"""Irregular sub-model partitioning (paper §2, Fig. 2 right).

Horn partitions the parent model into "multiple disconnected sub-models ...
[that] have the same input and output layers and share the weights", to
"reduce the size of model, improve the computing performance, and to get more
randomness".  This module is the planner around the per-step masks in
``parallel_dropout``:

  * :func:`plan` — given a model config + Horn config, the per-layer unit
    axes that sub-models are drawn over, block-aligned for the TPU kernel;
  * :func:`materialize` — extract group g's *actual smaller weights* (the
    paper's memory claim: a keep-0.5 sub-model's FFN weights are half-size) —
    used for sub-model export / distillation-style deployment;
  * :func:`stats` — compute/memory savings of a drawn sub-model (reported by
    ``benchmarks/submodel_flops.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HornConfig, ModelConfig
from repro.core import parallel_dropout as pdrop


@dataclass(frozen=True)
class SubmodelAxis:
    """One unit axis a sub-model is drawn over."""

    name: str            # e.g. "ffn_hidden", "ssm_channels", "moe_hidden"
    units: int
    keep: float
    block_size: int

    @property
    def n_blocks(self) -> int:
        return max(1, self.units // max(1, self.block_size))


def plan(cfg: ModelConfig, horn: HornConfig) -> List[SubmodelAxis]:
    """The sub-model axes for an architecture (DESIGN.md §5 table)."""
    axes: List[SubmodelAxis] = []
    bs = horn.block_size
    if cfg.d_ff > 0:
        axes.append(SubmodelAxis("ffn_hidden", cfg.d_ff, horn.keep_hidden, bs))
    if cfg.num_experts:
        axes.append(SubmodelAxis("moe_hidden", cfg.moe_ff, horn.keep_hidden, bs))
    if cfg.ssm_state:
        d_in = cfg.ssm_expand * cfg.d_model
        axes.append(SubmodelAxis("ssm_channels", d_in, horn.keep_hidden, bs))
    if horn.mask_attention_heads and cfg.has_attention:
        axes.append(SubmodelAxis("attn_heads", cfg.num_heads,
                                 horn.keep_hidden, 1))
    axes.append(SubmodelAxis("input_embed", cfg.d_model, horn.keep_input, bs))
    return axes


def draw(key, axis: SubmodelAxis, num_groups: int) -> jnp.ndarray:
    """[G, n_blocks] sub-model membership (values {0, 1/keep})."""
    return pdrop.group_block_mask(key, num_groups, axis.units, axis.keep,
                                  axis.block_size)


def materialize(wi: jnp.ndarray, wo: jnp.ndarray, mask_blocks: jnp.ndarray,
                block_size: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group g's *physically smaller* FFN weights.

    wi: [d, ff]; wo: [ff, d]; mask_blocks: [n_blocks] for ONE group.
    Returns (wi_kept [d, ff_kept], wo_kept [ff_kept, d]) — the paper's
    "reduction of memory usage": only the kept neurons' weights exist.
    """
    keep_cols = np.repeat(np.asarray(mask_blocks) > 0, block_size)
    keep_cols = keep_cols[: wi.shape[1]]
    idx = np.nonzero(keep_cols)[0]
    return jnp.take(wi, idx, axis=1), jnp.take(wo, idx, axis=0)


def materialize_units(mlp: Dict[str, jnp.ndarray], mask_units: np.ndarray,
                      *, pad_to: int = 0) -> Dict[str, jnp.ndarray]:
    """Per-unit sibling of :func:`materialize` for one MLP's params dict
    ({"wi" [d, ff], "wo" [ff, d], optional "wg" [d, ff]}): gathers the live
    hidden units of a *fixed* sub-model mask row ([ff] in {0, 1}) and
    zero-pads the kept axis up to ``pad_to`` columns.

    Zero padding is exact, not approximate: a zero ``wi`` column makes the
    unit's pre-activation 0, and silu/gelu/relu(0) == 0 (for gated MLPs the
    gate multiplies a 0 ``up``), so padded units contribute exactly nothing
    — which is what lets per-layer sub-models with different live counts
    share one stacked/scanned parameter shape (``ModelBank.materialize``).
    """
    idx = np.nonzero(np.asarray(mask_units) > 0)[0]
    pad = max(0, pad_to - len(idx))
    out: Dict[str, jnp.ndarray] = {}
    for name, w in mlp.items():
        axis = 0 if name == "wo" else 1
        kept = jnp.take(w, idx, axis=axis)
        if pad:
            widths = [(0, 0)] * w.ndim
            widths[axis] = (0, pad)
            kept = jnp.pad(kept, widths)
        out[name] = kept
    return out


def stats(cfg: ModelConfig, horn: HornConfig, key=None,
          num_groups: int = 8) -> Dict[str, float]:
    """Measured (not nominal) compute/memory savings of drawn sub-models."""
    key = key if key is not None else jax.random.key(0)
    out: Dict[str, float] = {}
    for i, axis in enumerate(plan(cfg, horn)):
        m = np.asarray(draw(jax.random.fold_in(key, i), axis, num_groups))
        dropped = float((m == 0).mean())
        out[f"{axis.name}_dropped_frac"] = dropped
        out[f"{axis.name}_flops_saved"] = dropped     # tiles skipped by kernel
        out[f"{axis.name}_weights_saved"] = dropped   # via materialize()
    return out
