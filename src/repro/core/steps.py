"""Step factories: pjit train_step / prefill / decode for every architecture.

This is the production entry point used by the launcher, the multi-pod
dry-run, and the benchmarks.  All distribution is expressed as logical-axis
shardings (launch/mesh.py); Horn parallel dropout is threaded through as a
first-class training feature; topology decides how group updates merge.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.parallel_dropout import make_horn_state
from repro.launch.mesh import ShardingCtx, sharding_rules, tree_shardings
from repro.models import api
from repro.models import transformer as T
from repro.models.params import cast_tree, param_axes
from repro.optim.sgd import clip_by_global_norm, make_optimizer

f32 = jnp.float32


def make_ctx(model_cfg: ModelConfig, mesh, shape=None) -> ShardingCtx:
    return ShardingCtx(mesh=mesh,
                       rules=sharding_rules(model_cfg, mesh, shape))


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------
def init_state(key, run: RunConfig):
    """{"params", "opt", "step", "rng"} — call under jit w/ out_shardings
    (or inside jax.eval_shape for the dry run)."""
    params = api.model_init(key, run.model)
    params = cast_tree(params, run.param_dtype)
    opt_init, _ = make_optimizer(run.optimizer)
    return {
        "params": params,
        "opt": opt_init(params),
        "step": jnp.zeros((), jnp.int32),
        "rng": jax.random.key_data(jax.random.key(run.seed)),
    }


def state_axes(run: RunConfig):
    paxes = api.model_axes(run.model)
    opt_init, _ = make_optimizer(run.optimizer)
    # optimizer-state leaves mirror param sharding (ZeRO-style: the "parameter
    # server" state lives wherever the param shard lives)
    if run.optimizer == "sgdm":
        opt_axes = {"mom": paxes}
    else:
        opt_axes = {"m": paxes, "v": paxes, "t": ()}
    return {"params": paxes, "opt": opt_axes, "step": (), "rng": (None,)}


def state_shardings(run: RunConfig, mesh):
    ctx = make_ctx(run.model, mesh, run.shape)
    return tree_shardings(state_axes(run), ctx)


# ---------------------------------------------------------------------------
# Input specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------
def batch_axes(run: RunConfig) -> Dict[str, Tuple]:
    cfg = run.model
    ax: Dict[str, Tuple] = {"tokens": ("batch", "seq"),
                            "labels": ("batch", "seq")}
    if cfg.is_encoder_decoder:
        ax["frames"] = ("batch", None, None)
    if cfg.num_patches:
        ax["patch_embeds"] = ("batch", None, None)
    return ax


def input_specs(run: RunConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Weak-type-correct, shardable stand-ins; no device allocation."""
    cfg, shape = run.model, run.shape
    B, S = shape.global_batch, shape.seq_len
    text = S - (cfg.num_patches if cfg.num_patches else 0)
    sd = jax.ShapeDtypeStruct
    specs = {"tokens": sd((B, text), jnp.int32),
             "labels": sd((B, text), jnp.int32)}
    if cfg.is_encoder_decoder:
        specs["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_patches:
        specs["patch_embeds"] = sd((B, cfg.num_patches, cfg.d_model),
                                   jnp.bfloat16)
    if shape.kind != "train":
        specs.pop("labels")
    return specs


def batch_shardings(run: RunConfig, mesh):
    ctx = make_ctx(run.model, mesh, run.shape)
    ax = batch_axes(run)
    if run.shape.kind != "train":
        ax.pop("labels", None)
    return {k: ctx.sharding(*v) for k, v in ax.items()}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(run: RunConfig, mesh):
    """Returns (jitted_step, shardings dict) — step(state, batch) -> (state, metrics)."""
    cfg = run.model
    ctx = make_ctx(cfg, mesh, run.shape)
    _, opt_update = make_optimizer(run.optimizer)
    dp = ctx.dp_size

    def loss_fn(params, batch, rng, step):
        horn = make_horn_state(jax.random.wrap_key_data(rng), run.horn, dp, step)
        return api.model_loss(params, batch, cfg, ctx, horn=horn,
                              remat=run.remat != "none")

    def train_step(state, batch):
        params, rng, step = state["params"], state["rng"], state["step"]
        cparams = cast_tree(params, run.compute_dtype)
        M = max(1, run.microbatches)
        if M == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(cparams, batch, rng, step)
        else:
            def split(x):
                return x.reshape((M, x.shape[0] // M) + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mb_i):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    cparams, mb_i, rng, step)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), cparams)
            (grads, loss), metrics = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), f32)), mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
            metrics = jax.tree.map(lambda x: jnp.mean(x, 0), metrics)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = opt_update(
            grads, state["opt"], params, lr=run.learning_rate,
            momentum=run.momentum, weight_decay=run.weight_decay
        ) if run.optimizer == "sgdm" else opt_update(
            grads, state["opt"], params, lr=run.learning_rate,
            weight_decay=run.weight_decay)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        new_state = {"params": new_params, "opt": new_opt,
                     "step": step + 1, "rng": rng}
        return new_state, metrics

    s_shard = tree_shardings(state_axes(run), ctx)
    b_shard = batch_shardings(run, mesh)
    jitted = jax.jit(train_step,
                     in_shardings=(s_shard, b_shard),
                     out_shardings=(s_shard, None),
                     donate_argnums=(0,))
    return jitted, {"state": s_shard, "batch": b_shard}


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(run: RunConfig, mesh):
    cfg = run.model
    ctx = make_ctx(cfg, mesh, run.shape)

    def prefill_step(params, batch):
        cparams = cast_tree(params, run.compute_dtype)
        logits, cache, enc = api.prefill(cparams, batch, cfg, ctx)
        return logits, cache, enc

    paxes = api.model_axes(cfg)
    p_shard = tree_shardings(paxes, ctx)
    b_shard = batch_shardings(run, mesh)
    jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                     out_shardings=None)
    return jitted, {"params": p_shard, "batch": b_shard}


def decode_cache_specs(run: RunConfig):
    """ShapeDtypeStructs for the decode cache at this shape cell."""
    cfg, shape = run.model, run.shape
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))


def make_decode_step(run: RunConfig, mesh):
    cfg = run.model
    ctx = make_ctx(cfg, mesh, run.shape)

    def decode_step(params, cache, tokens, pos, encoder_out=None):
        cparams = cast_tree(params, run.compute_dtype)
        return api.decode_step(cparams, cache, tokens, pos, cfg, ctx,
                               encoder_out=encoder_out)

    paxes = api.model_axes(cfg)
    p_shard = tree_shardings(paxes, ctx)
    from repro.launch.mesh import is_axes_leaf
    cache_struct = decode_cache_specs(run)
    c_axes = T.cache_logical_axes(cfg, cache_struct)
    c_shard = jax.tree.map(lambda ax: ctx.sharding(*ax), c_axes,
                           is_leaf=is_axes_leaf)
    tok_shard = ctx.sharding("batch", None)
    enc_shard = ctx.sharding("batch", None, None) if cfg.is_encoder_decoder else None
    in_sh = (p_shard, c_shard, tok_shard, None) + (
        (enc_shard,) if cfg.is_encoder_decoder else ())
    jitted = jax.jit(decode_step, in_shardings=in_sh,
                     out_shardings=(None, c_shard),
                     donate_argnums=(1,))
    return jitted, {"params": p_shard, "cache": c_shard,
                    "cache_struct": cache_struct}


# ---------------------------------------------------------------------------
# Unified paged serving step (continuous batching — see repro.serving)
# ---------------------------------------------------------------------------
def make_unified_paged_step(run: RunConfig, mesh, *, num_pages: int,
                            page_size: int, temperature: float = 0.0,
                            bank_masks=None, kv_dtype=jnp.bfloat16):
    """THE serving step: one jitted call per engine tick, whatever the tick
    holds.  The scheduler packs a token budget with a mix of decode tokens
    (one per running slot) and prompt chunks from admitting requests; the
    step appends every token's K/V to the page pool in place, runs chunked
    paged attention over the pool, and samples the next token for every
    slot on device (vectorized fold_in per (request, step) keys — no
    per-slot host loop, one device round-trip per tick).

    step(params, cache, tokens [B, C], starts [B], chunk_lens [B],
         block_tables [B, maxp], req_ids [B], sample_steps [B],
         submodel_ids [B], seg_ids [B], vote_flags [B], root_key)
      -> (sampled [B] int32, cache)

    Only the sampled tokens leave the step — returning the [B, V] logits
    would materialize a multi-MB output buffer per tick that no caller
    reads (at vocab 150k+ it would dwarf the transfer of everything else).

    ``C`` is the tick's chunk width: a decode-only tick runs at C == 1 (the
    classic paged-decode cell, bit-compatible with it); ticks carrying
    prompt chunks run at power-of-two C buckets (jit caches one executable
    per width).  The pool is donated so the K/V append is in-place.  Greedy
    when ``temperature <= 0``; otherwise categorical with per-slot keys
    ``fold_in(fold_in(root_key, req_id), step)`` — no key is ever reused
    across requests or steps.  Idle slots (chunk_len 0) and mid-prompt
    chunks produce samples the engine simply discards.

    Multi-submodel serving (``bank_masks`` = a ModelBank's mask tensors,
    leading axis G): each slot's circuit masks are gathered by
    ``submodel_ids`` *inside* the step, so decode tokens and prompt chunks
    from different sub-models co-batch in one jitted call — no per-submodel
    step, no recompile on routing decisions.  ``seg_ids`` [B] groups slots
    into ensembles (each slot carries its group leader's slot index; solo
    slots carry their own): per-step logits are segment-combined on device
    before sampling — mean-logit (members share the leader's sampling key,
    so one categorical draw decides the group) or, where ``vote_flags`` is
    set, a majority vote over member samples (ties -> lowest token id).
    Solo slots pass through both paths unchanged (a segment of one).

    ``ensembles`` is a static per-tick flag (two jit-compiled variants,
    dispatched host-side): ticks with no ensemble group in flight
    (routing-only serving, the common case) skip the combine machinery
    entirely — no [B, V] one-hot, no second sampling pass — at the cost of
    one extra compile per chunk-width bucket the first time an ensemble
    tick hits it.

    Speculative verify (``draft_lens``/``draft_probs``): a speculating
    slot's chunk is [pending token, d_1 .. d_dl] — the last committed
    token plus ``draft_lens[b]`` tokens a draft circuit proposed — and the
    step scores a *verify window* of S_v = draft_probs.shape[1] + 1
    positions per slot in the same single call (S_v is static via the
    ``draft_probs`` shape; the non-speculative engine always passes
    S_v == 1, which reduces bit-exactly to the classic last-position
    sampling path).  Greedy (temperature <= 0) accepts the longest prefix
    of drafts matching the parent argmax and emits the parent's token at
    the first mismatch (or the bonus token after d_dl when all match).
    With temperature > 0 the step runs standard rejection sampling against
    the draft distribution ``draft_probs`` (accept d_j with prob
    min(1, p_j(d_j)/q_j(d_j)); on rejection resample from
    norm(max(p - q, 0))) — byte-reproducible: every random draw folds in
    (req_id, sample_step + j) exactly like plain sampling, with a further
    fold_in(1)/fold_in(2) separating the accept-uniform and the resample
    from the bonus categorical.  Returns (sampled [B], accepted [B],
    cache): ``accepted[b]`` drafts are good, ``sampled[b]`` is the one
    verified-not-drafted token that follows them.  Non-speculating slots
    (draft_lens == 0, including every ensemble member) report accepted 0
    and sample at their last valid position as always.
    """
    cfg = run.model
    ctx = make_ctx(cfg, mesh, run.shape)

    def sample(logits, req_ids, sample_steps, root_key):
        if temperature > 0:
            keys = jax.vmap(lambda r, s: jax.random.fold_in(
                jax.random.fold_in(root_key, r), s))(req_ids, sample_steps)
            return jax.vmap(jax.random.categorical)(
                keys, logits.astype(f32) / temperature)
        return jnp.argmax(logits, axis=-1)

    def verify(logits_w, tokens, draft_lens, draft_probs, req_ids,
               sample_steps, root_key):
        """Accept/advance every slot against its verify window.

        logits_w: [B, S_v, V] — window position j holds the parent's
        distribution for the token AFTER chunk position j (speculating
        slots: chunk == window; plain slots: the window right-aligns on
        the last valid position, only j == S_v - 1 is meaningful).
        Returns (sampled [B], accepted [B])."""
        B, S_v, _ = logits_w.shape
        dl = draft_lens
        drafts = tokens[:, 1:S_v]                          # [B, S_v-1]
        tgt = jnp.argmax(logits_w, axis=-1)                # [B, S_v]
        if temperature <= 0:
            ok = (tgt[:, :S_v - 1] == drafts) \
                & (jnp.arange(S_v - 1)[None, :] < dl[:, None])
            acc = jnp.cumprod(ok.astype(jnp.int32), axis=-1).sum(axis=-1)
            # acc accepted drafts put the next decision at window position
            # acc — the correction when acc < dl, the bonus when acc == dl
            pick = jnp.where(dl > 0, acc, S_v - 1)
            sampled = jnp.take_along_axis(tgt, pick[:, None], axis=1)[:, 0]
            return sampled, acc
        lw = logits_w.astype(f32) / temperature
        kb = jax.vmap(lambda r: jax.random.fold_in(root_key, r))(req_ids)
        if S_v == 1:              # no drafts anywhere: classic sampling
            kp = jax.vmap(jax.random.fold_in)(kb, sample_steps)
            return jax.vmap(jax.random.categorical)(kp, lw[:, 0]), \
                jnp.zeros((B,), jnp.int32)
        p_w = jax.nn.softmax(lw, axis=-1)                  # [B, S_v, V]
        # the accept-uniform for draft j folds in the step the token would
        # occupy (sample_step + j), then salt 1 — never colliding with the
        # categorical draw at that step (no salt) or the resample (salt 2)
        jj = jnp.arange(S_v - 1)
        ukeys = jax.vmap(jax.vmap(
            lambda k, s: jax.random.fold_in(jax.random.fold_in(k, s), 1),
            in_axes=(None, 0)))(kb, sample_steps[:, None] + jj[None, :])
        u = jax.vmap(jax.vmap(jax.random.uniform))(ukeys)  # [B, S_v-1]
        pd = jnp.take_along_axis(p_w[:, :S_v - 1], drafts[..., None],
                                 axis=-1)[..., 0]
        qd = jnp.take_along_axis(draft_probs, drafts[..., None],
                                 axis=-1)[..., 0]
        ok = (u * jnp.maximum(qd, 1e-30) < pd) \
            & (jj[None, :] < dl[:, None])
        acc = jnp.cumprod(ok.astype(jnp.int32), axis=-1).sum(axis=-1)
        rejected = (dl > 0) & (acc < dl)
        pick = jnp.where(dl > 0, acc, S_v - 1)
        # the bonus/plain draw: categorical on the raw scaled logits with
        # the classic (req_id, step) key — at S_v == 1 this IS the
        # non-speculative sampling path, bit for bit
        kp = jax.vmap(jax.random.fold_in)(
            kb, sample_steps + jnp.where(dl > 0, pick, 0))
        lp = jnp.take_along_axis(
            lw, pick[:, None, None], axis=1)[:, 0]         # [B, V]
        bonus = jax.vmap(jax.random.categorical)(kp, lp)
        # the rejection resample: norm(max(p - q, 0)) at the first
        # rejected position (falls back to p when the residual vanishes —
        # q >= p everywhere means the accept test already passed a.s.)
        ridx = jnp.minimum(pick, S_v - 2)
        q_r = jnp.take_along_axis(
            draft_probs, ridx[:, None, None], axis=1)[:, 0]
        p_r = jnp.take_along_axis(p_w, ridx[:, None, None], axis=1)[:, 0]
        res = jnp.maximum(p_r - q_r, 0.0)
        res = jnp.where(res.sum(-1, keepdims=True) > 0, res, p_r)
        rkeys = jax.vmap(lambda k: jax.random.fold_in(k, 2))(kp)
        rtok = jax.vmap(jax.random.categorical)(
            rkeys, jnp.log(jnp.maximum(res, 1e-30)))
        sampled = jnp.where(rejected, rtok, bonus)
        return sampled, acc

    def unified_step(params, cache, tokens, starts, chunk_lens, block_tables,
                     req_ids, sample_steps, submodel_ids, seg_ids,
                     vote_flags, draft_lens, draft_probs, root_key, *,
                     ensembles=False):
        cparams = cast_tree(params, run.compute_dtype)
        serve_masks = None
        if bank_masks is not None:
            serve_masks = jax.tree.map(lambda m: m[submodel_ids], bank_masks)
        B = tokens.shape[0]
        S_v = draft_probs.shape[1] + 1
        j = jnp.arange(S_v)[None, :]
        cl = chunk_lens[:, None]
        # speculating slots verify their whole chunk (window == chunk,
        # left-aligned — a slot clamped below the tick's draft length just
        # ignores the tail); everyone else right-aligns on the last valid
        # position so j == S_v - 1 is the classic sampling position
        widx = jnp.where(draft_lens[:, None] > 0,
                         jnp.minimum(j, jnp.maximum(cl - 1, 0)),
                         jnp.clip(cl - S_v + j, 0, tokens.shape[1] - 1))
        logits_w, new_cache = api.paged_step(
            cparams, cache, tokens, starts, chunk_lens, block_tables,
            cfg, ctx, serve_masks=serve_masks, logit_index=widx)
        sampled, accepted = verify(logits_w, tokens, draft_lens,
                                   draft_probs, req_ids, sample_steps,
                                   root_key)
        if bank_masks is not None and ensembles:
            # ensemble members never speculate (draft_lens == 0): combine
            # their last-position logits exactly as before and let the
            # verify result stand for speculating / solo slots
            lf = jnp.take_along_axis(
                logits_w, jnp.where(draft_lens > 0, 0, S_v - 1)
                [:, None, None], axis=1)[:, 0].astype(f32)
            ones = jnp.ones((B,), f32)
            counts = jax.ops.segment_sum(ones, seg_ids, num_segments=B)
            mean = jax.ops.segment_sum(lf, seg_ids, num_segments=B) \
                / jnp.maximum(counts, 1.0)[:, None]
            # mean-logit: ensemble members carry the leader's req_id, so
            # identical keys sample the identical token from identical
            # combined logits; a segment of one divides by 1.0 (exact), so
            # a solo slot sharing the tick samples the same token either way
            mean_tok = sample(mean[seg_ids], req_ids, sample_steps, root_key)
            own_tok = sample(lf, req_ids, sample_steps, root_key)
            votes = jax.ops.segment_sum(
                jax.nn.one_hot(own_tok, lf.shape[-1], dtype=f32),
                seg_ids, num_segments=B)
            vote_tok = jnp.argmax(votes, axis=-1)[seg_ids]
            combined = jnp.where(vote_flags, vote_tok, mean_tok)
            sampled = jnp.where(draft_lens > 0, sampled, combined)
            accepted = jnp.where(draft_lens > 0, accepted, 0)
        return sampled.astype(jnp.int32), accepted.astype(jnp.int32), \
            new_cache

    paxes = api.model_axes(cfg)
    p_shard = tree_shardings(paxes, ctx)
    cache_struct = jax.eval_shape(
        lambda: T.init_paged_cache(cfg, num_pages, page_size,
                                   dtype=kv_dtype))
    variants = {
        flag: jax.jit(partial(unified_step, ensembles=flag),
                      in_shardings=(p_shard,) + (None,) * 13,
                      out_shardings=None, donate_argnums=(1,))
        for flag in (False, True)}

    def step(*args, ensembles: bool = False):
        return variants[ensembles](*args)

    # the observability profiler watches each variant's compile cache
    # and AOT-lowers them for cost_analysis attribution
    step.variants = variants

    return step, {"params": p_shard, "cache_struct": cache_struct}


def make_draft_spec_step(run: RunConfig, mesh, *, num_pages: int,
                         page_size: int, k: int, temperature: float = 0.0,
                         draft_salt: int = 0x5bec):
    """One jitted *draft tick* for speculative decoding: catch the draft
    circuit up on each slot's committed stream and autoregressively propose
    ``k`` tokens, all inside a single device call.

    step(params, cache, tokens [B, C], starts [B], chunk_lens [B],
         block_tables [B, maxp], req_ids [B], sample_steps [B], root_key)
      -> (drafts [B, k] int32, draft_probs [B, k, Vq] f32, cache)

    ``tokens`` is the catch-up chunk: the committed tokens the draft has
    not yet written K/V for, ending with the pending token (the one the
    parent will decode next), so the chunk's last-position logits propose
    d_1.  The remaining k - 1 proposals run as a ``lax.scan`` of C == 1
    paged steps feeding each draft back in — K sequential *model* steps
    but ONE host dispatch, which is what makes drafting cheaper than the
    K parent ticks it replaces.  K/V for d_1 .. d_{k-1} is appended to the
    draft's own page pool as it goes (d_k's K/V is written by the next
    tick's catch-up, exactly like the engine's pending token).

    Greedy drafts are argmax and ``draft_probs`` is a [B, k, 1] dummy;
    with temperature > 0 each proposal is a categorical draw under a
    *draft-private* key chain (root folded with ``draft_salt``, then
    (req_id, sample_step + i)) — independent of every verify-side draw by
    construction — and ``draft_probs`` carries the full proposal
    distribution q_i the verifier's rejection sampler needs.  ``k`` is
    static: the engine builds one step per draft length it actually runs
    (jit then caches per catch-up-width bucket)."""
    cfg = run.model
    ctx = make_ctx(cfg, mesh, run.shape)

    def sample(logits, req_ids, steps, droot):
        lf = logits.astype(f32)
        if temperature > 0:
            keys = jax.vmap(lambda r, s: jax.random.fold_in(
                jax.random.fold_in(droot, r), s))(req_ids, steps)
            tok = jax.vmap(jax.random.categorical)(keys, lf / temperature)
            q = jax.nn.softmax(lf / temperature, axis=-1)
        else:
            tok = jnp.argmax(lf, axis=-1)
            q = jnp.zeros(lf.shape[:-1] + (1,), f32)
        return tok.astype(jnp.int32), q

    def draft_step(params, cache, tokens, starts, chunk_lens, block_tables,
                   req_ids, sample_steps, root_key):
        cparams = cast_tree(params, run.compute_dtype)
        droot = jax.random.fold_in(root_key, draft_salt)
        logits, cache = api.paged_step(
            cparams, cache, tokens, starts, chunk_lens, block_tables,
            cfg, ctx)
        d0, q0 = sample(logits, req_ids, sample_steps, droot)
        if k == 1:
            return d0[:, None], q0[:, None], cache

        def body(carry, i):
            cache, tok, pos = carry
            lg, cache = api.paged_step(
                cparams, cache, tok[:, None], pos,
                jnp.ones_like(pos), block_tables, cfg, ctx)
            nt, q = sample(lg, req_ids, sample_steps + i, droot)
            return (cache, nt, pos + 1), (nt, q)

        (cache, _, _), (ds, qs) = jax.lax.scan(
            body, (cache, d0, starts + chunk_lens), jnp.arange(1, k))
        drafts = jnp.concatenate([d0[:, None], jnp.moveaxis(ds, 0, 1)], 1)
        probs = jnp.concatenate([q0[:, None], jnp.moveaxis(qs, 0, 1)], 1)
        return drafts, probs, cache

    paxes = api.model_axes(cfg)
    p_shard = tree_shardings(paxes, make_ctx(cfg, mesh, run.shape))
    return jax.jit(draft_step, in_shardings=(p_shard,) + (None,) * 8,
                   out_shardings=None, donate_argnums=(1,))


def make_page_copy_step():
    """Device-side KV page copy for copy-on-write: ``copy(cache, src, dst)``
    duplicates page ``src[i]`` into page ``dst[i]`` across every layer's
    K and V pool in one donated (in-place) call.

    ``src``/``dst`` are equal-length int32 arrays; callers pad them to a
    power-of-two width with (0, 0) pairs — copying the null page onto
    itself is a no-op by construction — so jit compiles one executable per
    width bucket, not per COW event.  Paged-cache leaves are
    [num_pages, psize, KH, D] pools or [num_pages, KH] int8-mode scale
    sidecars (remainder layers), each optionally prefixed by the scanned-
    superblock [R, ...] axis — the page axis is 0 for even rank, 1 for odd,
    and scale rows travel with their pages (COW / prefix-cache publishes
    never split a page from its scale)."""

    @partial(jax.jit, donate_argnums=(0,))
    def copy(cache, src, dst):
        def cp(x):
            if x.ndim % 2 == 0:              # [P, ...] pool or scale leaf
                return x.at[dst].set(x[src])
            return x.at[:, dst].set(x[:, src])   # [R, P, ...] scanned stack
        return jax.tree.map(cp, cache)

    return copy


def decode_input_specs(run: RunConfig):
    """(tokens, pos, [encoder_out]) ShapeDtypeStructs for decode cells."""
    cfg, shape = run.model, run.shape
    B = shape.global_batch
    sd = jax.ShapeDtypeStruct
    out = {"tokens": sd((B, 1), jnp.int32), "pos": sd((), jnp.int32)}
    if cfg.is_encoder_decoder:
        out["encoder_out"] = sd((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return out
