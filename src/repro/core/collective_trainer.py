"""Collective & Parallel Dropout training — the paper's §3 experiment engine.

Trains the neuron-centric MNIST network with G worker groups: each group
draws its own sub-model (dropout draw) per step, computes grads on its own
micro-batch, and updates are batch-averaged (AllReduce) or merged every H
steps (local SGD / Downpour).  Groups are a vmapped leading axis — on a TPU
mesh that axis is (pod, data); the math is identical (see group_sync docs),
which is what lets the CPU container reproduce the paper's accuracy claim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HornConfig, TopologyConfig
from repro.core import group_sync as gs
from repro.core.neuron_centric import NeuronNetwork, paper_mnist_network
from repro.core.parallel_dropout import HornState
from repro.data.mnist import load_mnist
from repro.data.pipeline import MnistBatcher
from repro.optim import compression as C

f32 = jnp.float32


@dataclass
class MnistResult:
    name: str
    accuracy: List[float] = field(default_factory=list)
    steps: List[int] = field(default_factory=list)
    final_accuracy: float = 0.0
    loss: List[float] = field(default_factory=list)
    data_source: str = ""

    def row(self):
        return {"name": self.name, "final_accuracy": self.final_accuracy,
                "steps": self.steps, "accuracy": self.accuracy,
                "data_source": self.data_source}


def make_step_fn(nn: NeuronNetwork, horn_cfg: HornConfig,
                 topology: TopologyConfig, lr: float, momentum: float,
                 num_groups: int):
    """jitted (params_g, mom_g, residual_g, batch_g, step) -> updated."""

    def group_loss(p, batch, gid, step):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(horn_cfg.seed_salt), step), gid)
        horn = (HornState(key=key, cfg=horn_cfg, num_groups=1)
                if horn_cfg.enabled else None)
        return nn.loss(p, batch, horn)

    @jax.jit
    def step_fn(params_g, mom_g, residual_g, batch_g, step):
        gids = jnp.arange(num_groups)
        loss_g, grads_g = jax.vmap(
            jax.value_and_grad(group_loss), in_axes=(0, 0, 0, None))(
                params_g, batch_g, gids, step)

        if topology.grad_compression == "int8":
            # compress each group's contribution (error feedback per group)
            q, s, residual_g = jax.vmap(C.ef_compress_tree)(grads_g, residual_g)
            grads_g = jax.tree.map(
                lambda qq, ss: qq.astype(f32)
                * ss.reshape((-1,) + (1,) * (qq.ndim - 1)), q, s)

        if topology.kind in ("allreduce", "zero1"):
            # batch averaging every step (paper's synchronous mode)
            grads_g = gs.broadcast_merged(grads_g)

        # momentum SGD per group (paper: w += -lr * v; v = mu*v + g)
        mom_g = jax.tree.map(lambda m, g: momentum * m + g, mom_g, grads_g)
        params_g = jax.tree.map(lambda p, m: p - lr * m, params_g, mom_g)

        if topology.kind == "local_sgd":
            params_g, mom_g = gs.maybe_merge_local_sgd(
                params_g, step, topology, momentum_g=mom_g)
        return params_g, mom_g, residual_g, jnp.mean(loss_g)

    return step_fn


def train_mnist(*, num_groups: int = 1, batch_per_group: int = 100,
                num_steps: int = 2000, lr: float = 0.3, momentum: float = 0.98,
                horn_cfg: Optional[HornConfig] = None,
                topology: Optional[TopologyConfig] = None,
                hidden: int = 512, depth: int = 2, seed: int = 0,
                eval_every: int = 500, n_train: int = 20000,
                data: Optional[dict] = None, name: str = "run") -> MnistResult:
    horn_cfg = horn_cfg or HornConfig(enabled=True, num_groups=num_groups,
                                      block_size=1)
    topology = topology or TopologyConfig(kind="allreduce")
    nn = paper_mnist_network(hidden=hidden, depth=depth)
    data = data or load_mnist(n_train=n_train)
    batcher = MnistBatcher(data["x_train"], data["y_train"],
                           batch_per_group * num_groups, seed=seed)
    test = {"x": jnp.asarray(data["x_test"]), "y": jnp.asarray(data["y_test"])}

    params = nn.init(jax.random.key(seed))
    params_g = gs.replicate_for_groups(params, num_groups)
    mom_g = jax.tree.map(lambda p: jnp.zeros_like(p, f32), params_g)
    residual_g = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params_g)
    step_fn = make_step_fn(nn, horn_cfg, topology, lr, momentum, num_groups)

    res = MnistResult(name=name, data_source=data.get("source", "?"))
    acc_fn = jax.jit(nn.accuracy)
    for step in range(num_steps):
        batch_np = batcher.group_batch_at(step, num_groups)
        batch_g = {"x": jnp.asarray(batch_np["x"]),
                   "y": jnp.asarray(batch_np["y"])}
        params_g, mom_g, residual_g, loss = step_fn(
            params_g, mom_g, residual_g, batch_g, step)
        if (step + 1) % eval_every == 0 or step == num_steps - 1:
            merged = gs.merge_groups_mean(params_g)
            acc = float(acc_fn(merged, test))
            res.steps.append(step + 1)
            res.accuracy.append(acc)
            res.loss.append(float(loss))
    res.final_accuracy = res.accuracy[-1] if res.accuracy else 0.0
    return res


def paper_comparison(*, num_steps: int = 2000, eval_every: int = 500,
                     lr: float = 0.3, momentum: float = 0.98,
                     seed: int = 0, n_train: int = 20000) -> Dict[str, MnistResult]:
    """The paper's Fig. 3: non-parallel (1 x batch 100) vs parallel
    (20 workers x batch 5, AllReduce) dropout training."""
    data = load_mnist(n_train=n_train)
    non_parallel = train_mnist(
        num_groups=1, batch_per_group=100, num_steps=num_steps, lr=lr,
        momentum=momentum, seed=seed, eval_every=eval_every, data=data,
        name="non-parallel dropout (1x100)")
    parallel = train_mnist(
        num_groups=20, batch_per_group=5, num_steps=num_steps, lr=lr,
        momentum=momentum, seed=seed, eval_every=eval_every, data=data,
        name="parallel dropout (20x5, AllReduce)")
    return {"non_parallel": non_parallel, "parallel": parallel}
