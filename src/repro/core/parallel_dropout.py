"""Collective & Parallel Dropout — Horn's core technique (paper §2).

Each worker *group* g draws an independent structured dropout over hidden
units ("a different disconnected sparse sub-model of the parent model") per
step; groups train in parallel on their data shards and updates are batch-
averaged.  On the TPU mesh, groups are slices of the (pod, data) batch axis, so
"different sub-model per group" is expressed as a mask tensor whose leading
axis is the group axis, broadcast against the group's samples.

Two faithfulness notes vs the 2016 paper:
  * The paper scales activations by the keep-rate at *eval* time; we use the
    mathematically equivalent inverted-dropout (scale 1/keep at train time).
    ``tests/test_parallel_dropout.py`` asserts the expectation equivalence.
  * The paper draws Bernoulli masks per neuron.  We draw per *block* of
    ``block_size`` contiguous neurons (default 128 = one TPU lane tile) so a
    dropped block is a skippable MXU tile (see kernels/dropout_matmul).
    ``block_size=1`` recovers the paper's exact per-neuron sub-models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import HornConfig

f32 = jnp.float32


@dataclass(frozen=True)
class HornState:
    """Per-step dropout context threaded through a model apply."""

    key: jax.Array            # per-step base RNG
    cfg: HornConfig
    num_groups: int           # resolved group count (>=1)

    def layer_key(self, layer_idx) -> jax.Array:
        return jax.random.fold_in(self.key, layer_idx)


def make_horn_state(key, cfg: HornConfig, dp_size: int, step) -> Optional[HornState]:
    if not cfg.enabled:
        return None
    groups = cfg.num_groups or max(1, dp_size)
    key = jax.random.fold_in(jax.random.fold_in(key, cfg.seed_salt), step)
    return HornState(key=key, cfg=cfg, num_groups=groups)


def group_block_mask(key, num_groups: int, units: int, keep: float,
                     block_size: int) -> jax.Array:
    """[num_groups, n_blocks] mask with values in {0, 1/keep} (inverted dropout).

    Guarantees at least one live block per group (a fully-dropped layer would
    sever the sub-model — Horn's sub-models stay connected input->output).
    """
    nb = max(1, units // max(1, block_size))
    u = jax.random.uniform(key, (num_groups, nb))
    live = u < keep
    # force the argmax-u block alive if a group drew all-dead
    fallback = jax.nn.one_hot(jnp.argmax(u, axis=-1), nb, dtype=bool)
    live = jnp.where(live.any(axis=-1, keepdims=True), live, fallback)
    return live.astype(f32) / keep


def expand_units(mask_blocks, units: int) -> jax.Array:
    """[G, nb] block mask -> [G, units] unit mask; the last block covers the
    remainder tail.  THE block->unit rule — train-time masks (expand_mask)
    and the serving ModelBank both go through here, so a trained sub-model
    and its served circuit can never disagree on which units a block owns."""
    G, nb = mask_blocks.shape
    per = units // nb
    m = jnp.repeat(mask_blocks, per, axis=-1)            # [G, nb*per]
    if units % nb:
        m = jnp.concatenate([m, jnp.broadcast_to(m[:, -1:], (G, units % nb))], -1)
    return m


def expand_mask(mask_blocks, units: int, batch: int) -> jax.Array:
    """[G, nb] -> [batch, 1, units]: group->sample expansion + block->unit."""
    G = mask_blocks.shape[0]
    m = expand_units(mask_blocks, units)                 # [G, units]
    reps = max(1, batch // G)
    m = jnp.repeat(m, reps, axis=0)[:batch]              # [batch, units]
    return m[:, None, :]


def unit_mask(state: Optional[HornState], layer_idx, batch: int, units: int,
              *, keep: Optional[float] = None, salt: int = 0,
              block_size: Optional[int] = None):
    """The mask a layer multiplies its hidden units by, or None in eval mode."""
    if state is None:
        return None
    keep = state.cfg.keep_hidden if keep is None else keep
    if keep >= 1.0:
        return None
    key = jax.random.fold_in(state.layer_key(layer_idx), salt)
    bs = state.cfg.block_size if block_size is None else block_size
    mb = group_block_mask(key, state.num_groups, units, keep, bs)
    return expand_mask(mb, units, batch)


def input_mask(state: Optional[HornState], batch: int, units: int):
    """Input-layer mask (paper: keep 0.8), applied to embedding channels."""
    if state is None:
        return None
    return unit_mask(state, 100_003, batch, units, keep=state.cfg.keep_input,
                     salt=7)


def head_mask(state: Optional[HornState], layer_idx, batch: int, heads: int):
    """Optional whole-attention-head dropout ([B, 1, H, 1]) — beyond-paper."""
    if state is None or not state.cfg.mask_attention_heads:
        return None
    m = unit_mask(state, layer_idx, batch, heads, salt=13, block_size=1)
    if m is None:
        return None
    return m[..., None]    # [B, 1, H, 1]
