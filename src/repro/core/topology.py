"""Topology selection — the paper's cluster-configuration knob (§2).

"By configuring the cluster topology, it also allows the user to use
different synchronous and asynchronous training techniques, such as
AllReduce and Downpour SGD."  The mapping to execution lives in
``group_sync`` / ``steps``; this module is the declarative surface:

    topo = TopologyConfig(kind="local_sgd", local_sgd_period=8,
                          grad_compression="int8")
"""
from __future__ import annotations

from repro.configs.base import TopologyConfig

DESCRIPTIONS = {
    "allreduce": "synchronous batch averaging every step (paper's MNIST mode)",
    "zero1": "sharded parameter-server: optimizer state sharded with params "
             "(reduce-scatter grads, shard-local update, all-gather params)",
    "local_sgd": "Downpour-SGD analogue: groups step independently for H "
                 "steps, then merge+broadcast (straggler-tolerant)",
}


def describe(topo: TopologyConfig) -> str:
    base = DESCRIPTIONS[topo.kind]
    if topo.kind == "local_sgd":
        base += f" (H={topo.local_sgd_period})"
    if topo.grad_compression != "none":
        base += f" + {topo.grad_compression} compressed merges w/ error feedback"
    return base


def validate(topo: TopologyConfig) -> TopologyConfig:
    assert topo.kind in DESCRIPTIONS, topo.kind
    assert topo.local_sgd_period >= 1
    assert topo.grad_compression in ("none", "int8")
    return topo
