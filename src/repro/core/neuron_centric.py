"""Neuron-centric programming model (paper §2), compiled to vectorized JAX.

The paper's API::

    nn.addLayer(512, ReLU.class, DropoutNeuron.class);

lets the user define per-neuron ``forward()``/``backward()`` message handlers
and an optional ``interlayer()`` normalization, while the *system* decides the
partitioning.  Per-neuron scalar message passing is hostile to the TPU MXU, so
— exactly as the paper's own Future Works proposes ("take a neuron-centric
model, and compile it to … code that batches for speed") — we keep the
declarative neuron-level API and compile it:

  * ``forward``'s weighted-sum-of-messages  ->  one matmul per layer
  * ``DropoutNeuron``'s per-neuron Bernoulli ->  Horn group masks
    (`core.parallel_dropout`), one fused elementwise multiply
  * ``interlayer`` normalization            ->  a vector->vector jnp function
  * ``backward``'s gradient messages + push() -> jax.grad + the topology's
    collective (AllReduce / ZeRO-1 / local-SGD merge)

The partition plan (which mesh axis each layer's units shard over) comes from
the same logical-axis rules the big models use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core import parallel_dropout as pdrop
from repro.models.params import ParamSpec, init_params, param_axes

f32 = jnp.float32

ACTIVATIONS = {
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
}


def softmax_interlayer(v):
    """The paper's canonical interlayer(): normalized (softmax) units."""
    return jax.nn.softmax(v, axis=-1)


def divide_by_sum_interlayer(v):
    """Literal paper example: output.divide(output.sum())."""
    return v / jnp.clip(jnp.sum(v, axis=-1, keepdims=True), 1e-9)


@dataclass(frozen=True)
class LayerSpec:
    units: int
    activation: str = "relu"
    neuron: str = "standard"          # standard | dropout  (DropoutNeuron.class)
    keep: Optional[float] = None      # dropout keep-rate; None -> Horn default
    interlayer: Optional[Callable] = None


@dataclass
class NeuronNetwork:
    """Builder mirroring the paper's ``nn.addLayer(...)`` API."""

    input_units: int
    input_neuron: str = "standard"    # "dropout" to drop input units (paper: 0.8)
    input_keep: Optional[float] = None
    layers: List[LayerSpec] = field(default_factory=list)

    def add_layer(self, units: int, activation: str = "relu",
                  neuron: str = "standard", keep: Optional[float] = None,
                  interlayer: Optional[Callable] = None) -> "NeuronNetwork":
        self.layers.append(LayerSpec(units, activation, neuron, keep, interlayer))
        return self

    # -- compiled artifacts ---------------------------------------------------
    def specs(self):
        specs = {}
        prev = self.input_units
        for i, l in enumerate(self.layers):
            specs[f"w{i}"] = ParamSpec((prev, l.units), ("embed", "ffn"),
                                       "normal", 2.0)
            specs[f"b{i}"] = ParamSpec((l.units,), ("ffn",), "zeros")
            prev = l.units
        return specs

    def init(self, key):
        return init_params(key, self.specs())

    def axes(self):
        return param_axes(self.specs())

    def apply(self, params, x, horn: Optional[pdrop.HornState] = None):
        """x: [B, input_units] -> output of last layer.

        DropoutNeuron layers multiply by the group's sub-model mask — the
        vectorized form of the paper's ``m2 = getBinomial(1, 0.5)`` neuron code.
        Per-neuron granularity (block_size=1) is used here, exactly as in the
        paper; the 128-block variant is the LM-scale beyond-paper option.
        """
        B = x.shape[0]
        if self.input_neuron == "dropout":
            m = pdrop.unit_mask(horn, 100_003, B, self.input_units,
                                keep=self.input_keep or
                                (horn.cfg.keep_input if horn else None),
                                salt=7, block_size=1)
            if m is not None:
                x = x * m[:, 0]
        for i, l in enumerate(self.layers):
            x = x @ params[f"w{i}"] + params[f"b{i}"]       # sum of messages
            x = ACTIVATIONS[l.activation](x)                # apply(sum)
            last = i == len(self.layers) - 1
            if l.neuron == "dropout" and not last:
                m = pdrop.unit_mask(horn, i, B, l.units, keep=l.keep,
                                    salt=5, block_size=1)
                if m is not None:
                    x = x * m[:, 0]                          # feedforward(out*m)
            if l.interlayer is not None:
                x = l.interlayer(x)
        return x

    def loss(self, params, batch, horn=None):
        """Softmax cross-entropy (paper's Softmax + Cross Entropy head)."""
        logits = self.apply(params, batch["x"], horn)
        logp = jax.nn.log_softmax(logits.astype(f32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
        return nll.mean()

    def accuracy(self, params, batch):
        logits = self.apply(params, batch["x"], horn=None)
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(f32))


def paper_mnist_network(hidden: int = 512, depth: int = 2) -> NeuronNetwork:
    """The MNIST MLP of paper §3: ReLU hiddens (DropoutNeuron), softmax head."""
    nn = NeuronNetwork(input_units=784, input_neuron="dropout", input_keep=0.8)
    for _ in range(depth):
        nn.add_layer(hidden, "relu", neuron="dropout", keep=0.5)
    nn.add_layer(10, "identity", neuron="standard")
    return nn
