"""Group synchronization — Horn's topologies (paper §2, Fig. 1).

The paper's cluster runs worker *groups*: BSP-synchronous inside a group
("region barrier synchronization"), asynchronous between groups, merging
through a parameter server (AllReduce or Downpour SGD).  TPU-idiomatic
mapping:

  allreduce   — every step, grads are batch-averaged across all groups.  In
                the pjit path GSPMD inserts the all-reduce; in the shard_map
                path we call psum explicitly (optionally int8-compressed).
  local_sgd   — Downpour's stand-in inside SPMD: each group keeps its own
                params for H steps, then all groups average (the paper's
                "weight parameters are merged and broadcasted ... in
                synchronous way" with a merge period).  Also the straggler
                answer: between merges no group waits for another.
  zero1       — the "task acts as a parameter server" role, sharded: optimizer
                state lives sharded across chips (reduce-scatter grads,
                shard-local update, all-gather params).  With our FSDP
                sharding rules this is expressed through out_shardings.

``simulate_groups``: on a single host (tests, the MNIST repro), groups are a
vmapped leading axis — mathematically identical to the multi-chip layout where
that axis is the (pod, data) mesh dim.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TopologyConfig
from repro.optim import compression as C

f32 = jnp.float32


# ---------------------------------------------------------------------------
# Group replication / merging (vmap simulation and shard_map variants)
# ---------------------------------------------------------------------------
def replicate_for_groups(tree, num_groups: int):
    """params -> per-group copies with leading [G] axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_groups,) + x.shape), tree)


def merge_groups_mean(tree):
    """Batch averaging (paper): mean over the leading group axis."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def broadcast_merged(tree, num_groups: int = 0):
    if not num_groups:
        num_groups = jax.tree.leaves(tree)[0].shape[0]
    return replicate_for_groups(merge_groups_mean(tree), num_groups)


def psum_mean(tree, axis_names):
    n = 1.0
    for a in (axis_names if isinstance(axis_names, tuple) else (axis_names,)):
        n = n * jax.lax.psum(1.0, a)
    return jax.tree.map(lambda x: jax.lax.psum(x.astype(f32), axis_names) / n,
                        tree)


def merge_grads(grads, axis_names, topology: TopologyConfig, residuals=None):
    """Explicit (shard_map) gradient merge with optional int8 error feedback.

    Returns (merged_grads, new_residuals).
    """
    if topology.grad_compression == "int8":
        q, s, new_res = C.ef_compress_tree(grads, residuals)
        return C.psum_mean_compressed(q, s, axis_names), new_res
    return psum_mean(grads, axis_names), residuals


# ---------------------------------------------------------------------------
# Local SGD (period-H merge) — group-async Downpour analogue
# ---------------------------------------------------------------------------
def maybe_merge_local_sgd(params_g, step, topology: TopologyConfig,
                          *, momentum_g=None):
    """Every H steps, average the per-group params (and momentum) and
    re-broadcast; otherwise pass through.  params_g: [G, ...] pytrees."""
    H = max(1, topology.local_sgd_period)
    G = jax.tree.leaves(params_g)[0].shape[0]

    def merge(t):
        merged = broadcast_merged(t, G)
        return merged

    do = (step % H) == (H - 1)
    params_out = jax.tree.map(
        lambda x: jnp.where(do, jnp.broadcast_to(jnp.mean(x, 0, keepdims=True),
                                                 x.shape), x), params_g)
    if momentum_g is None:
        return params_out, None
    mom_out = jax.tree.map(
        lambda x: jnp.where(do, jnp.broadcast_to(jnp.mean(x, 0, keepdims=True),
                                                 x.shape), x), momentum_g)
    return params_out, mom_out


def group_drift(params_g) -> jnp.ndarray:
    """Mean L2 distance of each group's params from the group average —
    the regularization 'diversity' Horn's sub-models induce (metric only)."""
    def one(x):
        mu = jnp.mean(x, axis=0, keepdims=True)
        return jnp.sum(jnp.square(x.astype(f32) - mu.astype(f32)))
    total = sum(jax.tree.leaves(jax.tree.map(one, params_g)))
    return jnp.sqrt(total)
