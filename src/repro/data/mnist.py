"""MNIST data for the paper's §3 experiment.

Offline container: if a real MNIST npz is present (``MNIST_PATH`` env or
``data/mnist.npz``), we use it.  Otherwise we fall back to a *procedural*
digit dataset: 28x28 renders of a 7-segment-style glyph per class with random
shift / scale / noise / stroke-width jitter.  It is learnable but non-trivial
(a linear model does NOT saturate it), so the paper's parallel-vs-non-parallel
dropout comparison remains meaningful.  The source is recorded in benchmark
output so results are interpretable.
"""
from __future__ import annotations

import os
from typing import Dict, Tuple

import numpy as np

# 7-segment encodings per digit: (top, top-l, top-r, mid, bot-l, bot-r, bottom)
_SEGS = {
    0: (1, 1, 1, 0, 1, 1, 1), 1: (0, 0, 1, 0, 0, 1, 0),
    2: (1, 0, 1, 1, 1, 0, 1), 3: (1, 0, 1, 1, 0, 1, 1),
    4: (0, 1, 1, 1, 0, 1, 0), 5: (1, 1, 0, 1, 0, 1, 1),
    6: (1, 1, 0, 1, 1, 1, 1), 7: (1, 0, 1, 0, 0, 1, 0),
    8: (1, 1, 1, 1, 1, 1, 1), 9: (1, 1, 1, 1, 0, 1, 1),
}


def _render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    # glyph box with random placement/size
    x0 = rng.integers(4, 9)
    y0 = rng.integers(3, 7)
    w = rng.integers(10, 14)
    h = rng.integers(14, 18)
    t = rng.integers(2, 4)          # stroke width
    top, tl, tr, mid, bl, br, bot = _SEGS[digit]
    ym = y0 + h // 2
    if top:
        img[y0:y0 + t, x0:x0 + w] = 1
    if bot:
        img[y0 + h - t:y0 + h, x0:x0 + w] = 1
    if mid:
        img[ym - t // 2: ym - t // 2 + t, x0:x0 + w] = 1
    if tl:
        img[y0:ym, x0:x0 + t] = 1
    if bl:
        img[ym:y0 + h, x0:x0 + t] = 1
    if tr:
        img[y0:ym, x0 + w - t:x0 + w] = 1
    if br:
        img[ym:y0 + h, x0 + w - t:x0 + w] = 1
    # amplitude jitter + blur-ish smoothing + noise
    img *= rng.uniform(0.7, 1.0)
    img += rng.normal(0, 0.15, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)


def synthetic_mnist(n_train: int = 20000, n_test: int = 2000,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    def make(n):
        ys = rng.integers(0, 10, n).astype(np.int32)
        xs = np.stack([_render_digit(int(y), rng) for y in ys])
        return xs.reshape(n, 784).astype(np.float32), ys
    xtr, ytr = make(n_train)
    xte, yte = make(n_test)
    return {"x_train": xtr, "y_train": ytr, "x_test": xte, "y_test": yte,
            "source": "synthetic-7seg"}


def load_mnist(n_train: int = 20000, n_test: int = 2000,
               seed: int = 0) -> Dict[str, np.ndarray]:
    path = os.environ.get("MNIST_PATH", "data/mnist.npz")
    if os.path.exists(path):
        z = np.load(path)
        return {"x_train": z["x_train"].reshape(-1, 784).astype(np.float32) / 255.0,
                "y_train": z["y_train"].astype(np.int32),
                "x_test": z["x_test"].reshape(-1, 784).astype(np.float32) / 255.0,
                "y_test": z["y_test"].astype(np.int32),
                "source": f"mnist:{path}"}
    return synthetic_mnist(n_train, n_test, seed)
