"""Deterministic, resumable, sharded data pipelines.

Fault-tolerance contract: a batch is a pure function of (seed, step), so
recovery after preemption replays *exactly* the batches that would have been
consumed — no sampler state to checkpoint, no duplicate/dropped batches on
restore (the step counter in the train state is the only cursor).

On a real multi-host deployment each host materializes only its slice
(``host_slice``); under pjit the global batch is assembled via
``jax.make_array_from_process_local_data``.  On one host we build the global
array directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


class SyntheticTokenPipeline:
    """Markov-ish synthetic token stream (structured enough that loss falls)."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab_size, 4096)
        self._table = base.integers(0, v, size=(v, 4)).astype(np.int32)
        self._v = v

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch for `step` — pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._v, B)
        noise = rng.integers(0, 4, size=(B, S))
        explore = rng.random((B, S)) < 0.1
        rand_tok = rng.integers(0, self._v, (B, S))
        for t in range(S):
            nxt = self._table[toks[:, t], noise[:, t]]
            toks[:, t + 1] = np.where(explore[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_slice(self, step: int) -> Dict[str, np.ndarray]:
        b = self.batch_at(step)
        per = self.cfg.global_batch // self.cfg.num_hosts
        lo = self.cfg.host_id * per
        return {k: v[lo:lo + per] for k, v in b.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MnistBatcher:
    """Step-indexed MNIST batcher (same determinism contract)."""

    def __init__(self, x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0):
        self.x, self.y, self.batch, self.seed = x, y, batch, seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, len(self.x), self.batch)
        return {"x": self.x[idx], "y": self.y[idx]}

    def group_batch_at(self, step: int, num_groups: int) -> Dict[str, np.ndarray]:
        """[G, B/G, ...] batches — each Horn group gets its own data shard."""
        b = self.batch_at(step)
        per = self.batch // num_groups
        return {k: v[: per * num_groups].reshape((num_groups, per) + v.shape[1:])
                for k, v in b.items()}
