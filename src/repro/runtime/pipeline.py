"""Pipeline parallelism (GPipe schedule) via shard_map + ppermute.

The ``pod`` axis of the production mesh can serve as the pipeline-stage axis:
each stage owns a contiguous slice of layers (stacked params sharded on the
layer dim), microbatches flow stage->stage through collective-permutes.

Forward is an explicit tick loop (T = M + S - 1); because ppermute is
differentiable (its transpose is the reverse permute), ``jax.grad`` through
:func:`pipelined_apply` yields the reverse-schedule backward automatically —
no hand-written 1F1B needed for correctness.  ``tests/test_pipeline.py``
checks forward and grad equality vs the unpipelined reference on a 4-stage
CPU mesh.

Bubble fraction is (S-1)/(M+S-1); callers pick M >= 4*S to keep it under 20%.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipelined_apply(block_fn: Callable, stage_params, x_mb, *,
                    mesh: Mesh, stage_axis: str = "stage"):
    """Run ``block_fn`` over pipeline stages.

    block_fn(stage_params_slice, x) -> x   (applies ONE stage's layers)
    stage_params: pytree with leading dim = num_stages (sharded over stages)
    x_mb: [M, mb, ...] microbatches (replicated input)
    Returns [M, mb, ...] outputs (replicated — result of the last stage).
    """
    S = mesh.shape[stage_axis]
    M = x_mb.shape[0]
    T = M + S - 1
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    def stage_prog(params_slice, x_local):
        # params_slice: [1, ...] this stage's layer stack; squeeze stage dim
        params_here = jax.tree.map(lambda p: p[0], params_slice)
        idx = jax.lax.axis_index(stage_axis)

        def tick(carry, t):
            act, outs = carry
            mb_id = t - idx
            inject = x_local[jnp.clip(t, 0, M - 1)]
            act_in = jnp.where(idx == 0, inject, act)
            out = block_fn(params_here, act_in)
            valid = (mb_id >= 0) & (mb_id < M)
            out = jnp.where(valid, out, jnp.zeros_like(out))
            # last stage records its finished microbatch
            rec_id = jnp.clip(mb_id, 0, M - 1)
            record = (idx == S - 1) & valid
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, out, outs[rec_id]), rec_id, 0)
            nxt = jax.lax.ppermute(out, stage_axis, fwd_perm) if fwd_perm else out
            return (nxt, outs), None

        act0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros_like(x_local)
        (act, outs), _ = jax.lax.scan(tick, (act0, outs0), jnp.arange(T))
        # broadcast result from the last stage to all (so output is replicated)
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), stage_axis)
        return outs

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(stage_prog, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_mb)


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
