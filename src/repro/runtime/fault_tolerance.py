"""Fault tolerance & straggler mitigation for long-running training.

What runs for real in this container vs what is a deployment hook is stated
explicitly — nothing here pretends to more than it does:

  * Preemption-safe training loop: SIGTERM/SIGINT triggers an immediate
    checkpoint + clean exit; restart resumes from (step, rng) with the
    deterministic data pipeline (real, tested).
  * Crash recovery: restore_latest_good walks back over corrupted
    checkpoints (real, tested).
  * NaN/overflow guard: a non-finite loss or grad-norm skips the update and
    (after `patience` consecutive) rolls back to the last checkpoint — the
    single-program analogue of "evict the bad worker" (real, tested).
  * Straggler mitigation: Horn's own design — group asynchrony.  With
    topology=local_sgd groups only synchronize every H steps, so a slow
    group delays merges, not every step (the merge math is real; the
    multi-host scheduling benefit is a deployment property).
  * Node-failure handling at scale (deployment hook): on a real cluster the
    coordinator restarts the job on the surviving slice; because checkpoints
    reshard elastically (checkpoint/checkpointer.py) the job continues on a
    smaller mesh.  ``elastic.remesh_state`` implements the reshard step.
"""
from __future__ import annotations

import signal
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np


class PreemptionHandler:
    """Latches SIGTERM/SIGINT; the train loop polls ``should_stop``."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handle)
            except ValueError:      # non-main thread (tests)
                pass

    def _handle(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def trigger(self) -> None:      # for tests / manual drills
        self._stop = True


@dataclass
class NanGuard:
    """Skip non-finite updates; escalate to rollback after `patience` hits."""

    patience: int = 3
    consecutive: int = field(default=0, init=False)
    total_skipped: int = field(default=0, init=False)

    def check(self, loss) -> str:
        """Returns 'ok' | 'skip' | 'rollback'."""
        if np.isfinite(float(loss)):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.total_skipped += 1
        return "rollback" if self.consecutive >= self.patience else "skip"


def fault_tolerant_loop(*, state, step_fn, batch_at: Callable[[int], dict],
                        checkpointer, num_steps: int,
                        checkpoint_every: int = 100,
                        state_shardings=None,
                        preemption: Optional[PreemptionHandler] = None,
                        nan_guard: Optional[NanGuard] = None,
                        on_metrics: Optional[Callable] = None):
    """The production inner loop: deterministic data, periodic async
    checkpoints, NaN guard with rollback, preemption-safe exit.

    Returns (state, last_step, exit_reason).
    """
    preemption = preemption or PreemptionHandler()
    nan_guard = nan_guard or NanGuard()
    step = int(np.asarray(jax.tree.leaves(state["step"])[0]))
    last_good = step
    while step < num_steps:
        if preemption.should_stop:
            checkpointer.wait()
            checkpointer.save(step, state, blocking=True)
            return state, step, "preempted"
        new_state, metrics = step_fn(state, batch_at(step))
        verdict = nan_guard.check(metrics["loss"])
        if verdict == "ok":
            state = new_state
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % checkpoint_every == 0:
                checkpointer.save(step, state, blocking=False)
                last_good = step
        elif verdict == "skip":
            step += 1           # drop this batch, keep params
        else:                   # rollback
            checkpointer.wait()
            state, restored = checkpointer.restore_latest_good(
                state, shardings=state_shardings)
            step = int(restored)
            nan_guard.consecutive = 0
    checkpointer.wait()
    checkpointer.save(step, state, blocking=True)
    return state, step, "completed"
