"""Elastic scaling: continue a run on a different mesh.

A v5e pod losing a host drops 8 chips; the job restarts on e.g. (14, 16) or a
half-pod (8, 16).  Because all shardings are *logical*, remeshing is:

    new_mesh  = make_mesh(new_shape)
    new_rules = sharding_rules(cfg, new_mesh)   # divisibility-aware fallbacks
    state     = remesh_state(state, axes, new_ctx)

The divisibility fallbacks in ``sharding_rules`` mean a dimension that no
longer divides (e.g. 16 kv-heads on a 12-way model axis) degrades to
replication instead of failing — the run continues, just less sharded.
"""
from __future__ import annotations

import jax

from repro.launch.mesh import ShardingCtx, sharding_rules, tree_shardings


def remesh_state(state, state_axes, new_ctx: ShardingCtx):
    """Re-lay-out a (possibly host-resident) state pytree onto a new mesh."""
    sh = tree_shardings(state_axes, new_ctx)

    def put(x, s):
        if s is None:
            return jax.device_put(x)
        return jax.device_put(x, s)

    return jax.tree.map(put, state, sh,
                        is_leaf=lambda x: not isinstance(x, dict))


def valid_meshes(n_devices: int):
    """Factorizations (data, model) usable after losing nodes."""
    out = []
    for model in (1, 2, 4, 8, 16):
        if n_devices % model == 0:
            out.append((n_devices // model, model))
    return out
