"""Request router over a ModelBank: tags every request with a submodel_id.

Three policies (ISSUE/ROADMAP "multi-submodel routing"):

  "explicit"      the caller names the circuit (``submodel_id=...``); the
                  router only validates the id.
  "hash"          stable affinity: the same session key (or, failing that,
                  the same prompt bytes) always lands on the same circuit —
                  useful when callers want a *consistent* sub-model per
                  conversation without pinning ids themselves.
  "least_loaded"  balance in-flight requests: pick the circuit with the
                  fewest live requests (ties -> lowest id).  The engine
                  reports completions back via ``release``.

An explicit ``submodel_id`` always wins regardless of policy.  The router
is pure host-side bookkeeping — the engine gathers the chosen circuit's
masks on device per slot, so routing never costs a recompile.
"""
from __future__ import annotations

import zlib
from typing import Optional

import numpy as np

POLICIES = ("explicit", "hash", "least_loaded")


class Router:
    def __init__(self, num_submodels: int, *, policy: str = "least_loaded"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        if num_submodels < 1:
            raise ValueError("router needs at least one submodel")
        self.num_submodels = num_submodels
        self.policy = policy
        self.loads = [0] * num_submodels    # in-flight requests per circuit
        self.routed = [0] * num_submodels   # lifetime assignments (stats)

    def _check(self, g: int) -> int:
        if not 0 <= g < self.num_submodels:
            raise ValueError(
                f"submodel_id {g} not in [0, {self.num_submodels})")
        return g

    def _hash_key(self, session, prompt) -> bytes:
        if session is not None:
            return str(session).encode()
        if prompt is None:
            raise ValueError("hash policy needs a session key or a prompt")
        return np.ascontiguousarray(prompt, dtype=np.int32).tobytes()

    def route(self, *, submodel_id: Optional[int] = None, session=None,
              prompt=None) -> int:
        """Pick (and account for) the circuit serving one new request."""
        if submodel_id is not None:
            g = self._check(int(submodel_id))
        elif self.policy == "explicit":
            raise ValueError("policy 'explicit' requires submodel_id")
        elif self.policy == "hash":
            g = zlib.crc32(self._hash_key(session, prompt)) \
                % self.num_submodels
        else:                               # least_loaded
            g = min(range(self.num_submodels), key=lambda i: self.loads[i])
        self.loads[g] += 1
        self.routed[g] += 1
        return g

    def acquire(self, g: int) -> int:
        """Account for a request pinned to ``g`` outside ``route`` (e.g.
        one member of an ensemble fan-out)."""
        g = self._check(g)
        self.loads[g] += 1
        self.routed[g] += 1
        return g

    def release(self, g: int) -> None:
        """A request on circuit ``g`` finished (engine callback)."""
        self._check(g)
        if self.loads[g] <= 0:
            raise ValueError(f"release without matching route on {g}")
        self.loads[g] -= 1

    def stats(self) -> dict:
        """Per-circuit load/assignment snapshot for the telemetry layer."""
        return {
            "policy": self.policy,
            "loads": {g: n for g, n in enumerate(self.loads)},
            "routed": {g: n for g, n in enumerate(self.routed)},
        }
