"""Continuous-batching inference engine over the paged KV cache.

One engine tick = one jitted device call, whatever the tick holds.  The
scheduler fills a fixed *token budget* with a mix of decode tokens (one per
running slot) and prompt chunks from admitting requests; the unified paged
step appends every token's K/V to the page pool in place, runs chunked paged
attention, and returns on-device-sampled next tokens for every slot.  A
32k-token admission therefore costs each in-flight request at most
``token_budget`` tokens of latency per tick — never a monolithic prefill
stall.

Positions are per-slot: slot b's chunk starts at the number of KV tokens it
already has in pages, so a fresh 7-token request and a 900-token-deep one
advance in the same device step.  Sampling keys are derived per (request,
step) via vectorized fold_in inside the step — no key is ever reused across
requests or steps, and no per-slot host loop touches the logits.

Pool pressure under the ``on_demand`` policy no longer kills the server:
the engine preempts the youngest running sequence back to the head of the
waiting queue (pages freed, KV recomputed on re-admission through the same
chunked-prefill path) and degrades to lower throughput.  ``EngineOOM`` is
reserved for genuinely unservable states — a single sequence that can never
fit the pool even alone.

Chunk widths are bucketed to powers of two so the unified step compiles
once per width, not once per chunk length; a decode-only tick runs the
C == 1 cell, bit-compatible with the classic paged-decode step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, LOCAL, HornConfig, ModelConfig,
                                RunConfig, ShapeConfig)
from repro.core import steps as S
from repro.models import transformer as T
from repro.serving.kv_cache import PagePool, PagePoolOOM
from repro.serving.scheduler import FCFSScheduler, Request


class EngineOOM(RuntimeError):
    """The page pool cannot serve a sequence even after preempting every
    other running sequence (e.g. one request's context alone exceeds the
    pool).  The engine state is left consistent; callers should surface
    this and exit cleanly."""


@dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8               # decode batch width
    num_pages: int = 256             # pool size (page 0 is the null page)
    page_size: int = 16              # tokens per KV page
    max_prompt_len: int = 256
    max_new_tokens: int = 64         # default + hard cap per request
    token_budget: int = 256          # tokens per unified tick (decode+chunks)
    temperature: float = 0.0
    seed: int = 0
    policy: str = "reserve"          # "reserve" | "on_demand" (see scheduler)
    eos_id: Optional[int] = None
    kv_dtype: str = "bfloat16"       # page-pool dtype (float32 for parity tests)
    compute_dtype: str = "bfloat16"  # model compute dtype

    @property
    def max_model_len(self) -> int:
        return self.max_prompt_len + self.max_new_tokens


# tick-entry record: what one slot contributes to this tick's device call
@dataclass
class _Entry:
    req: Request
    start: int                       # KV tokens already in pages
    tokens: np.ndarray               # [chunk_len] int32
    chunk_len: int
    sample_step: int                 # fold_in step for the sampling key
    record: bool                     # keep the sampled token?


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 mesh=None):
        bad = [k for k in cfg.layer_pattern if k not in (ATTN, LOCAL)]
        if bad or cfg.is_encoder_decoder or cfg.num_patches or cfg.learned_pos:
            raise ValueError(
                f"paged serving supports decoder-only attention LMs; "
                f"{cfg.name} has {bad or 'an unsupported input frontend'}")
        if ecfg.max_prompt_len % ecfg.page_size:
            raise ValueError("max_prompt_len must be page-aligned")
        if ecfg.token_budget < ecfg.num_slots:
            raise ValueError(
                f"token_budget ({ecfg.token_budget}) must cover one decode "
                f"token per slot ({ecfg.num_slots})")
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        self.pool = PagePool(ecfg.num_pages, ecfg.page_size)
        self.sched = FCFSScheduler(ecfg.num_slots, self.pool,
                                   policy=ecfg.policy)
        self.max_pages_per_seq = self.pool.pages_for(ecfg.max_model_len)

        run = RunConfig(model=cfg,
                        shape=ShapeConfig("serve", "decode",
                                          ecfg.max_model_len, ecfg.num_slots),
                        horn=HornConfig(enabled=False),
                        compute_dtype=ecfg.compute_dtype)
        self._step, _ = S.make_unified_paged_step(
            run, mesh, num_pages=ecfg.num_pages, page_size=ecfg.page_size,
            temperature=ecfg.temperature)
        self.cache = T.init_paged_cache(cfg, ecfg.num_pages, ecfg.page_size,
                                        dtype=jnp.dtype(ecfg.kv_dtype))

        B = ecfg.num_slots
        # chunk widths are clamped so every compile cell is a power of two
        # <= bucket(max_chunk): a preempted request's re-prefill (up to
        # max_model_len - 1 kv tokens) just takes one extra tick instead of
        # minting a wider compile cell no warmup sweep would have seen
        self.max_chunk = min(ecfg.token_budget, ecfg.max_prompt_len)
        self._block_tables = np.zeros((B, self.max_pages_per_seq), np.int32)
        self._root_key = jax.random.key(ecfg.seed)
        self._next_id = 0
        self.steps = 0
        self.generated_tokens = 0
        self.prefill_tokens = 0
        self.peak_utilization = 0.0

    @property
    def preemptions(self) -> int:
        return self.sched.preemptions

    def reset_stats(self) -> None:
        """Zero the serving counters without touching compile caches or the
        pool — benchmarks warm up on the engine they measure (a fresh Engine
        would also mean a fresh jit cache) and then discard the warmup's
        contribution here."""
        self.steps = 0
        self.generated_tokens = 0
        self.prefill_tokens = 0
        self.peak_utilization = 0.0
        self.sched.preemptions = 0
        self.sched.finished.clear()

    # -- request intake ------------------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               arrival_time: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 0 < len(prompt) <= self.ecfg.max_prompt_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, "
                f"{self.ecfg.max_prompt_len}]")
        mnt = min(max_new_tokens or self.ecfg.max_new_tokens,
                  self.ecfg.max_new_tokens)
        req = Request(id=self._next_id, prompt=prompt, max_new_tokens=mnt,
                      arrival_time=arrival_time, eos_id=self.ecfg.eos_id)
        # reject requests that could never be admitted even into an empty
        # pool — otherwise they'd pin the FCFS head and the drive loop would
        # spin forever waiting for pages that cannot exist
        need = self.sched.admission_pages(req)
        if need > self.pool.capacity:
            raise ValueError(
                f"request needs {need} page(s) at admission "
                f"(policy={self.ecfg.policy}) but the pool has only "
                f"{self.pool.capacity}; raise num_pages or shrink "
                f"prompt/max_new_tokens")
        self._next_id += 1
        self.sched.submit(req)
        return req

    # -- internals -----------------------------------------------------------
    def _chunk_bucket(self, n: int) -> int:
        """Power-of-two chunk-width bucket (bounds unified-step retraces)."""
        return 1 << max(0, int(n - 1).bit_length())

    def _sync_slot(self, req: Request) -> None:
        """Mirror the pool's page table into the device block-table row."""
        table = self.pool.table(req.id)
        row = self._block_tables[req.slot]
        row[:] = 0
        row[:len(table)] = table

    def _sample_peak(self) -> None:
        self.peak_utilization = max(self.peak_utilization,
                                    self.pool.utilization())

    def _clock(self, now: Optional[float]) -> float:
        return time.monotonic() if now is None else now

    # -- tick planning -------------------------------------------------------
    def _plan_tick(self) -> Dict[int, _Entry]:
        """Fill the token budget: one decode token per decode-phase slot,
        then prompt chunks for prefill-phase slots in admission order.
        Preempts the youngest running sequence (and replans) whenever decode
        growth hits pool pressure; raises EngineOOM only when no preemption
        can help."""
        while True:
            try:
                return self._try_plan()
            except PagePoolOOM as e:
                if self.sched.preempt_youngest() is None:
                    raise EngineOOM(
                        f"tick {self.steps}: {e}; no other sequence left to "
                        f"preempt — this request can never fit; raise "
                        f"--pages, lower --gen, or use --policy reserve"
                        ) from e

    def _try_plan(self) -> Dict[int, _Entry]:
        entries: Dict[int, _Entry] = {}
        budget = self.ecfg.token_budget
        decode, prefill = [], []
        for slot, req in sorted(self.sched.running.items()):
            (prefill if req.in_prefill else decode).append((slot, req))

        for slot, req in decode:
            self.sched.grow(req)                 # may raise PagePoolOOM
            entries[slot] = _Entry(
                req=req, start=req.context_len - 1,
                tokens=np.asarray([req.out_tokens[-1]], np.int32),
                chunk_len=1, sample_step=len(req.out_tokens), record=True)
            budget -= 1
        # prompt chunks soak up whatever budget the decode tokens left,
        # oldest admission first (it holds pages; finish it soonest)
        prefill.sort(key=lambda sr: sr[1].admit_seq)
        for slot, req in prefill:
            kv = req.kv_tokens
            want = len(kv) - req.prefill_pos
            cl = min(want, max(budget, 0), self.max_chunk)
            if cl <= 0:
                continue                          # budget exhausted this tick
            finishes = req.prefill_pos + cl == len(kv)
            entries[slot] = _Entry(
                req=req, start=req.prefill_pos,
                tokens=kv[req.prefill_pos:req.prefill_pos + cl],
                chunk_len=cl, sample_step=0,
                # the chunk that completes a *fresh* prompt yields the first
                # token; a preempted request's next token is already known
                record=finishes and not req.out_tokens)
            budget -= cl
        return entries

    # -- one engine tick -----------------------------------------------------
    def step(self, now: Optional[float] = None,
             tick_clock=None) -> List[Request]:
        """Admit + advance every running slot by one unified device call.
        Returns the requests that finished this tick.  Pass ``tick_clock``
        (a zero-arg callable on the same epoch as ``now``) for an honest
        post-tick timestamp; without it every event in the tick shares
        ``now``."""
        now = self._clock(now)
        tick_now = tick_clock if tick_clock else (lambda: now)
        self.sched.admit(now)
        self._sample_peak()                       # admissions allocate pages
        done = self.sched.evict_finished(tick_now())  # e.g. max_new_tokens==1
        if not self.sched.running:
            self._null_empty_slots()
            if self.sched.waiting:
                # a preempted request's context can outgrow the whole pool;
                # with nothing running and the FCFS head unadmittable even
                # into an empty pool, the drive loop would spin forever
                head = self.sched.waiting[0]
                need = self.sched.admission_pages(head)
                if need > self.pool.capacity:
                    raise EngineOOM(
                        f"request {head.id} needs {need} page(s) to "
                        f"re-admit but the pool has only "
                        f"{self.pool.capacity}; its context can never "
                        f"fit — raise --pages or lower --gen")
            return done

        entries = self._plan_tick()
        self._sample_peak()                       # decode growth allocates too
        self._null_empty_slots()                  # preemption vacates slots
        for slot in entries:
            self._sync_slot(self.sched.running[slot])
        if not entries:                           # nothing runnable this tick
            return done

        B = self.ecfg.num_slots
        C = self._chunk_bucket(max(e.chunk_len for e in entries.values()))
        tokens = np.zeros((B, C), np.int32)
        starts = np.zeros((B,), np.int32)
        chunk_lens = np.zeros((B,), np.int32)
        req_ids = np.zeros((B,), np.int32)
        sample_steps = np.zeros((B,), np.int32)
        for slot, e in entries.items():
            tokens[slot, :e.chunk_len] = e.tokens
            starts[slot] = e.start
            chunk_lens[slot] = e.chunk_len
            req_ids[slot] = e.req.id
            sample_steps[slot] = e.sample_step

        sampled, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(starts), jnp.asarray(chunk_lens),
            jnp.asarray(self._block_tables), jnp.asarray(req_ids),
            jnp.asarray(sample_steps), self._root_key)
        sampled = np.asarray(sampled)             # forces the tick
        self.steps += 1
        post = tick_now()

        for slot, e in entries.items():
            req = e.req
            if req.in_prefill:
                req.prefill_pos += e.chunk_len
                self.prefill_tokens += e.chunk_len
            if e.record:
                self.sched.record_token(slot, int(sampled[slot]), post)
                self.generated_tokens += 1

        finished = self.sched.evict_finished(post)
        self._null_empty_slots()
        return done + finished

    def _null_empty_slots(self) -> None:
        """Point every vacated slot's block-table row at the null page."""
        for slot in set(range(self.ecfg.num_slots)) - set(self.sched.running):
            self._block_tables[slot] = 0

    def run(self, *, clock=None) -> List[Request]:
        """Drive until every submitted request has finished."""
        clock = clock or time.monotonic
        while self.sched.has_work():
            self.step(clock())
        return self.sched.finished
