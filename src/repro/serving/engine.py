"""Continuous-batching inference engine over the paged KV cache.

One engine tick = (admit new requests -> bucketed batch-1 prefill scattered
into pages) + (one fused paged-decode step advancing every running slot one
token).  Requests of arbitrary prompt length join whenever a slot and pages
are free and leave the moment they finish — the decode batch never drains.

Positions are per-slot: slot b's write position is ``context_len - 1`` (the
last sampled token whose KV hasn't been written yet), so a fresh 7-token
request and a 900-token-deep one advance in the same device step.  Sampling
keys are derived per (request, step) via fold_in — no key is ever reused
across requests or steps (the bug the old static-batch server had).

Prompt lengths are bucketed to page-aligned powers of two so the prefill
step compiles once per bucket, not once per length.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, LOCAL, HornConfig, ModelConfig,
                                RunConfig, ShapeConfig)
from repro.core import steps as S
from repro.models import transformer as T
from repro.serving.kv_cache import PagePool, PagePoolOOM
from repro.serving.scheduler import FCFSScheduler, Request


class EngineOOM(RuntimeError):
    """Page pool exhausted mid-decode (on_demand policy).  The engine state
    is left consistent; callers should surface this and exit cleanly."""


@dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8               # decode batch width
    num_pages: int = 256             # pool size (page 0 is the null page)
    page_size: int = 16              # tokens per KV page
    max_prompt_len: int = 256
    max_new_tokens: int = 64         # default + hard cap per request
    temperature: float = 0.0
    seed: int = 0
    policy: str = "reserve"          # "reserve" | "on_demand" (see scheduler)
    eos_id: Optional[int] = None
    kv_dtype: str = "bfloat16"       # page-pool dtype (float32 for parity tests)
    compute_dtype: str = "bfloat16"  # model compute dtype

    @property
    def max_model_len(self) -> int:
        return self.max_prompt_len + self.max_new_tokens


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 mesh=None):
        bad = [k for k in cfg.layer_pattern if k not in (ATTN, LOCAL)]
        if bad or cfg.is_encoder_decoder or cfg.num_patches or cfg.learned_pos:
            raise ValueError(
                f"paged serving supports decoder-only attention LMs; "
                f"{cfg.name} has {bad or 'an unsupported input frontend'}")
        if ecfg.max_prompt_len % ecfg.page_size:
            raise ValueError("max_prompt_len must be page-aligned")
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        self.pool = PagePool(ecfg.num_pages, ecfg.page_size)
        self.sched = FCFSScheduler(ecfg.num_slots, self.pool,
                                   policy=ecfg.policy)
        self.max_pages_per_seq = self.pool.pages_for(ecfg.max_model_len)

        run = RunConfig(model=cfg,
                        shape=ShapeConfig("serve", "decode",
                                          ecfg.max_model_len, ecfg.num_slots),
                        horn=HornConfig(enabled=False),
                        compute_dtype=ecfg.compute_dtype)
        self._prefill, _ = S.make_serve_prefill_step(run, mesh)
        self._decode, _ = S.make_paged_decode_step(
            run, mesh, num_pages=ecfg.num_pages, page_size=ecfg.page_size)
        self._write = S.make_prefill_write_step(run, ecfg.page_size)
        self.cache = T.init_paged_cache(cfg, ecfg.num_pages, ecfg.page_size,
                                        dtype=jnp.dtype(ecfg.kv_dtype))

        B = ecfg.num_slots
        self._block_tables = np.zeros((B, self.max_pages_per_seq), np.int32)
        self._root_key = jax.random.key(ecfg.seed)
        self._next_id = 0
        self.steps = 0
        self.generated_tokens = 0
        self.peak_utilization = 0.0

    # -- request intake ------------------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               arrival_time: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 0 < len(prompt) <= self.ecfg.max_prompt_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, "
                f"{self.ecfg.max_prompt_len}]")
        mnt = min(max_new_tokens or self.ecfg.max_new_tokens,
                  self.ecfg.max_new_tokens)
        req = Request(id=self._next_id, prompt=prompt, max_new_tokens=mnt,
                      arrival_time=arrival_time, eos_id=self.ecfg.eos_id)
        # reject requests that could never be admitted even into an empty
        # pool — otherwise they'd pin the FCFS head and the drive loop would
        # spin forever waiting for pages that cannot exist
        need = self.sched.admission_pages(req)
        if need > self.ecfg.num_pages - 1:
            raise ValueError(
                f"request needs {need} page(s) at admission "
                f"(policy={self.ecfg.policy}) but the pool has only "
                f"{self.ecfg.num_pages - 1}; raise num_pages or shrink "
                f"prompt/max_new_tokens")
        self._next_id += 1
        self.sched.submit(req)
        return req

    # -- internals -----------------------------------------------------------
    def _bucket(self, n: int) -> int:
        """Page-aligned power-of-two prompt bucket (bounds retraces)."""
        ps = self.ecfg.page_size
        b = ps * (1 << max(0, math.ceil(math.log2(-(-n // ps)))))
        return min(b, self.ecfg.max_prompt_len)

    def _sample(self, logits, req: Request, step: int) -> int:
        if self.ecfg.temperature <= 0:
            return int(np.argmax(np.asarray(logits)))
        key = jax.random.fold_in(
            jax.random.fold_in(self._root_key, req.id), step)
        return int(jax.random.categorical(
            key, jnp.asarray(logits) / self.ecfg.temperature))

    def _sync_slot(self, req: Request) -> None:
        """Mirror the pool's page table into the device block-table row."""
        table = self.pool.table(req.id)
        row = self._block_tables[req.slot]
        row[:] = 0
        row[:len(table)] = table

    def _admit(self, now: float, tick_clock=None) -> None:
        """``tick_clock`` (optional) re-reads the clock after each prefill so
        same-tick admissions get honest TTFT stamps (batch-1 prefills are
        serial; the first and eighth admission of a tick are seconds apart)."""
        for req in self.sched.admit(now):
            L = req.prompt_len
            bucket = self._bucket(L)
            tok = np.zeros((1, bucket), np.int32)
            tok[0, :L] = req.prompt
            logits, kv = self._prefill(self.params, {"tokens": jnp.asarray(tok)},
                                       jnp.asarray([L - 1], jnp.int32))
            # scatter prompt KV into this sequence's pages; tiles past the
            # prompt's pages go to the null page (id 0) and are never read
            table = self.pool.table(req.id)
            n_prompt = self.pool.pages_for(L)
            pid = np.zeros(bucket // self.ecfg.page_size, np.int32)
            pid[:n_prompt] = table[:n_prompt]
            self.cache = self._write(self.cache, kv, jnp.asarray(pid))
            tok0 = self._sample(logits[0], req, 0)      # forces the prefill
            self.sched.record_token(
                req.slot, tok0, tick_clock() if tick_clock else now)
            self._sync_slot(req)

    def _clock(self, now: Optional[float]) -> float:
        return time.monotonic() if now is None else now

    # -- one engine tick -----------------------------------------------------
    def step(self, now: Optional[float] = None,
             tick_clock=None) -> List[Request]:
        """Admit + decode one token for every running slot.  Returns the
        requests that finished this tick.  Pass ``tick_clock`` (a zero-arg
        callable on the same epoch as ``now``) for per-admission TTFT stamps;
        without it every admission in the tick shares ``now``."""
        now = self._clock(now)
        tick_now = tick_clock if tick_clock else (lambda: now)
        self._admit(now, tick_clock)
        done = self.sched.evict_finished(tick_now())  # e.g. max_new_tokens == 1
        self._null_empty_slots()
        if not self.sched.running:
            return done

        B = self.ecfg.num_slots
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        for slot, req in self.sched.running.items():
            try:
                self.sched.grow(req)
            except PagePoolOOM as e:
                raise EngineOOM(
                    f"decode step {self.steps}: {e}; running={len(self.sched.running)} "
                    f"waiting={len(self.sched.waiting)} — raise --pages, lower "
                    f"--slots, or use --policy reserve") from e
            self._sync_slot(req)
            tokens[slot, 0] = req.out_tokens[-1]
            positions[slot] = req.context_len - 1   # last token's KV write pos
        self.peak_utilization = max(self.peak_utilization,
                                    self.pool.utilization())

        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(self._block_tables))
        logits = np.asarray(logits)                 # forces the decode step
        self.steps += 1
        post = tick_now()                           # after prefills + decode
        for slot, req in list(self.sched.running.items()):
            self.sched.record_token(
                slot, self._sample(logits[slot], req, len(req.out_tokens)),
                post)
            self.generated_tokens += 1

        finished = self.sched.evict_finished(post)
        self._null_empty_slots()
        return done + finished

    def _null_empty_slots(self) -> None:
        """Point every vacated slot's block-table row at the null page."""
        for slot in set(range(self.ecfg.num_slots)) - set(self.sched.running):
            self._block_tables[slot] = 0

    def run(self, *, clock=None) -> List[Request]:
        """Drive until every submitted request has finished."""
        clock = clock or time.monotonic
        while self.sched.has_work():
            self.step(clock())
        return self.sched.finished
