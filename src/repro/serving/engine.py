"""Continuous-batching inference engine over the paged KV cache.

One engine tick = one jitted device call, whatever the tick holds.  The
scheduler fills a fixed *token budget* with a mix of decode tokens (one per
running slot) and prompt chunks from admitting requests; the unified paged
step appends every token's K/V to the page pool in place, runs chunked paged
attention, and returns on-device-sampled next tokens for every slot.  A
32k-token admission therefore costs each in-flight request at most
``token_budget`` tokens of latency per tick — never a monolithic prefill
stall.

Positions are per-slot: slot b's chunk starts at the number of KV tokens it
already has in pages, so a fresh 7-token request and a 900-token-deep one
advance in the same device step.  Sampling keys are derived per (request,
step) via vectorized fold_in inside the step — no key is ever reused across
requests or steps, and no per-slot host loop touches the logits.

Pool pressure under the ``on_demand`` policy no longer kills the server:
the engine preempts the youngest running sequence back to the head of the
waiting queue (page references released, KV recomputed on re-admission
through the same chunked-prefill path) and degrades to lower throughput.
``EngineOOM`` is reserved for genuinely unservable states — a single
sequence that can never fit the pool even alone.

Chunk widths are bucketed to powers of two so the unified step compiles
once per width, not once per chunk length; a decode-only tick runs the
C == 1 cell, bit-compatible with the classic paged-decode step.

Multi-submodel serving (Horn §2 at inference): pass a ``ModelBank`` and the
engine serves its G parallel circuits behind the same scheduler and page
pool — a ``Router`` tags each request with a ``submodel_id``, the unified
step gathers that slot's fixed circuit masks on device, and tokens from
different sub-models co-batch in one tick.  ``submit(..., ensemble=...)``
fans one prompt across all G circuits in lockstep and combines their
per-step logits on device (mean-logit or majority vote) before sampling —
the paper's collective ensemble served as one request.

Prefix caching + copy-on-write (``EngineConfig.prefix_cache``, default on):
full prompt pages are content-addressed by a rolling hash chained over
their token blocks, retired pages are held (LRU) by the pool's
``PrefixCache``, and admission adopts the longest cached page-prefix so
chunked prefill starts mid-prompt — a shared system prompt is prefilled
once across millions of requests.  An ensemble's shared prompt context
(positions [0, prompt_len - 1), dense-parent encoded — circuit masks
engage at the last prompt token) is the degenerate case: the leader
prefills it once, every member forks the pages (refcount G), and only
per-member decode tails copy-on-write on divergence — ensemble prefill
costs ~1/G of the re-prefill path, byte-identically.

The host->device block-table mirror is synced incrementally: only rows
whose page tables changed since the last device call are re-uploaded
(steady decode inside a page uploads nothing).

Speculative decoding (``EngineConfig.speculate_k`` + a
``ModelBank.draft_model``): each speculating decode slot first runs the
materialized draft circuit for up to K tokens — one jitted draft call per
tick, batched across slots, against the draft's private page pool
(``serving/speculative.py``) — then the parent verifies all K+1 positions
inside the SAME single token-budget call the tick would have made anyway
(a verify chunk is a K+1-token chunk through the existing chunk-append
path, scored over a window of logits).  Greedy acceptance is the longest
draft prefix matching the parent argmax — byte-identical to sequential
greedy decode — and temperature > 0 runs on-device rejection sampling
against the draft distribution, byte-reproducible per (req_id,
sample_step) fold_in.  A rejected tail rolls back by releasing page
references (``PagePool.truncate_seq``), never by copying.  The budget
meters parent compute: a speculating slot consumes 1 + K verified tokens,
drafted tokens are free."""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, LOCAL, HornConfig, ModelConfig,
                                RunConfig, ShapeConfig)
from repro.core import steps as S
from repro.models import transformer as T
from repro.serving.block_table import (BlockTableMirror, marshal_i32,
                                       pow2_bucket)
from repro.serving.kv_cache import PagePool, PagePoolOOM, kv_page_bytes
from repro.serving.model_bank import DraftModel, ModelBank
from repro.serving.observability import EngineStats, Telemetry
from repro.serving.router import Router
from repro.serving.scheduler import (EnsembleGroup, FCFSScheduler, Request,
                                     speculative_draft_len)
from repro.serving.speculative import DraftRunner

COMBINES = ("mean_logit", "majority_vote")


def _unified_step_key(args, kw):
    """Compile-cell label for the profiler: the unified step
    specialises on the chunk-width bucket (tokens arg), the verify
    window extent (draft_probs arg), and the static ensembles flag —
    everything else is shape-fixed per engine.  Cheap: three attribute
    reads per tick, no pytree walk."""
    c = args[2].shape[1]                  # tokens [B, C]
    sv = args[12].shape[1] + 1            # draft_probs [B, S_v - 1, V]
    ens = bool(kw.get("ensembles", False))
    return (c, sv, ens), f"C={c},Sv={sv},ens={ens}"


class EngineOOM(RuntimeError):
    """The page pool cannot serve a sequence even after preempting every
    other running sequence (e.g. one request's context alone exceeds the
    pool).  The engine state is left consistent; callers should surface
    this and exit cleanly."""


@dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8               # decode batch width
    num_pages: int = 256             # pool size (page 0 is the null page)
    page_size: int = 16              # tokens per KV page
    max_prompt_len: int = 256
    max_new_tokens: int = 64         # default + hard cap per request
    token_budget: int = 256          # tokens per unified tick (decode+chunks)
    temperature: float = 0.0
    seed: int = 0
    policy: str = "reserve"          # "reserve" | "on_demand" (see scheduler)
    eos_id: Optional[int] = None
    kv_dtype: str = "bfloat16"       # page-pool dtype: float32 (parity
                                     # tests) | bfloat16 | int8 (quantized
                                     # pools + per-(page, head) f32 scale
                                     # sidecars — ~2x pages at equal HBM,
                                     # bounded-error decode)
    compute_dtype: str = "bfloat16"  # model compute dtype
    pages_per_step: int = 1          # KV pages per paged-kernel grid step
                                     # (>1 double-buffers page DMAs; output
                                     # is bit-identical across values)
    prefix_cache: bool = True        # content-addressed page reuse + COW
                                     # (off: PR-3-style per-request prefill)
    speculate_k: int = 0             # draft tokens verified per decode tick
                                     # (0: no speculation; > 0 needs a
                                     # DraftModel passed to the Engine)

    @property
    def max_model_len(self) -> int:
        return self.max_prompt_len + self.max_new_tokens


# tick-entry record: what one slot contributes to this tick's device call
@dataclass
class _Entry:
    req: Request
    start: int                       # KV tokens already in pages
    tokens: np.ndarray               # [chunk_len] int32
    chunk_len: int
    sample_step: int                 # fold_in step for the sampling key
    record: bool                     # keep the sampled token?
    mask_id: int                     # circuit-mask row the step gathers for
                                     # this chunk (the dense sentinel for an
                                     # ensemble's shared prompt context)
    draft_len: int = 0               # drafted tokens this chunk verifies
                                     # (tokens[1:1+draft_len] are proposals)


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 mesh=None, *, bank: Optional[ModelBank] = None,
                 router: Optional[Router] = None,
                 draft: Optional[DraftModel] = None,
                 telemetry: Optional[Telemetry] = None):
        bad = [k for k in cfg.layer_pattern if k not in (ATTN, LOCAL)]
        if bad or cfg.is_encoder_decoder or cfg.num_patches or cfg.learned_pos:
            raise ValueError(
                f"paged serving supports decoder-only attention LMs; "
                f"{cfg.name} has {bad or 'an unsupported input frontend'}")
        if ecfg.max_prompt_len % ecfg.page_size:
            raise ValueError("max_prompt_len must be page-aligned")
        if ecfg.token_budget < ecfg.num_slots:
            raise ValueError(
                f"token_budget ({ecfg.token_budget}) must cover one decode "
                f"token per slot ({ecfg.num_slots})")
        self.cfg, self.ecfg = cfg, ecfg
        self.params = params
        self.bank = bank
        if bank is not None:
            if bank.cfg != cfg:
                raise ValueError(
                    f"bank was built for {bank.cfg.name}, engine serves "
                    f"{cfg.name}")
            self.router = router if router is not None \
                else Router(bank.num_submodels)
            if self.router.num_submodels != bank.num_submodels:
                raise ValueError(
                    f"router spans {self.router.num_submodels} submodels, "
                    f"bank holds {bank.num_submodels}")
        elif router is not None:
            raise ValueError("a Router needs a ModelBank to route over")
        else:
            self.router = None
        self.pool = PagePool(ecfg.num_pages, ecfg.page_size,
                             prefix_cache=ecfg.prefix_cache)
        self.sched = FCFSScheduler(ecfg.num_slots, self.pool,
                                   policy=ecfg.policy)
        self.max_pages_per_seq = self.pool.pages_for(ecfg.max_model_len)
        # mask row the unified step gathers for dense-parent chunks (an
        # ensemble's shared prompt context): device_masks pads an all-ones
        # row at index G
        self._dense_mask_id = bank.num_submodels if bank is not None else 0
        if ecfg.speculate_k > 0:
            if draft is None:
                raise ValueError(
                    "speculate_k > 0 needs a DraftModel "
                    "(ModelBank.draft_model) to propose tokens")
            if draft.cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft.cfg.vocab_size} != parent vocab "
                    f"{cfg.vocab_size} — drafted ids would be meaningless")
            self.spec: Optional[DraftRunner] = DraftRunner(draft, ecfg, mesh)
        elif draft is not None:
            raise ValueError("a DraftModel needs speculate_k > 0 to be used")
        else:
            self.spec = None

        run = RunConfig(model=cfg,
                        shape=ShapeConfig("serve", "decode",
                                          ecfg.max_model_len, ecfg.num_slots),
                        horn=HornConfig(enabled=False),
                        compute_dtype=ecfg.compute_dtype)
        # telemetry before the jitted step: the profiler wraps it and
        # must see its very first (warmup) compile
        self.obs = telemetry if telemetry is not None else Telemetry()
        # static kernel tuning knob, read at trace time — set before the
        # first jitted step is traced (see kernels/paged_attention/ops.py)
        from repro.kernels.paged_attention import ops as _pops
        _pops.set_pages_per_step(ecfg.pages_per_step)
        self._step, _ = S.make_unified_paged_step(
            run, mesh, num_pages=ecfg.num_pages, page_size=ecfg.page_size,
            temperature=ecfg.temperature,
            bank_masks=bank.device_masks() if bank is not None else None,
            kv_dtype=jnp.dtype(ecfg.kv_dtype))
        if self.obs.profiler is not None:
            self._step = self.obs.profiler.wrap(
                "unified_step", self._step, key_fn=_unified_step_key)
        self._page_copy = S.make_page_copy_step()
        self.cache = T.init_paged_cache(cfg, ecfg.num_pages, ecfg.page_size,
                                        dtype=jnp.dtype(ecfg.kv_dtype))

        B = ecfg.num_slots
        # chunk widths are clamped so every compile cell is a power of two
        # <= bucket(max_chunk): a preempted request's re-prefill (up to
        # max_model_len - 1 kv tokens) just takes one extra tick instead of
        # minting a wider compile cell no warmup sweep would have seen
        self.max_chunk = min(ecfg.token_budget, ecfg.max_prompt_len)
        # incremental block-table sync (shared with the draft runner —
        # serving/block_table.py): per-slot (req_id, admit_seq,
        # table_version) keys decide which ROWS re-upload; the pool bumps
        # a sequence's version on every table mutation (page appended,
        # adopted, COW- or rollback-swapped), and admit_seq keys a
        # preempt/re-admit cycle that lands the same request back in its
        # old slot.
        self._bt = BlockTableMirror(B, self.max_pages_per_seq)
        # the S_v == 1 verify window of a tick with no speculating slot
        self._noprobs = jnp.zeros((B, 0, 1), jnp.float32)
        self._root_key = jax.random.key(ecfg.seed)
        self._next_id = 0
        self._next_group_id = 0
        # serving counters live on an EngineStats dataclass (observability/
        # stats.py); module-level properties below keep every counter
        # readable/writable as a plain engine attribute
        self.stats = EngineStats()
        self._evictions_base = 0         # pool evictions at last reset
        # estimated HBM bytes one tick's paged attention reads per live
        # KV page across all layers (roofline gauges; see kv_page_bytes)
        self._kv_bytes_per_page = cfg.num_layers * kv_page_bytes(
            ecfg.page_size, cfg.num_kv_heads, cfg.head_dim, ecfg.kv_dtype)
        # stamp the tuning knobs into exported traces + metrics snapshots
        # — two traces from differently-configured engines must be
        # distinguishable without filenames
        self.obs.set_engine_config(
            kv_dtype=ecfg.kv_dtype, compute_dtype=ecfg.compute_dtype,
            pages_per_step=ecfg.pages_per_step,
            speculate_k=ecfg.speculate_k,
            bank_size=bank.num_submodels if bank is not None else 0,
            num_slots=ecfg.num_slots, num_pages=ecfg.num_pages,
            page_size=ecfg.page_size, token_budget=ecfg.token_budget,
            max_prompt_len=ecfg.max_prompt_len,
            max_new_tokens=ecfg.max_new_tokens, policy=ecfg.policy,
            prefix_cache=ecfg.prefix_cache,
            temperature=ecfg.temperature, seed=ecfg.seed)

    @property
    def preemptions(self) -> int:
        return self.sched.preemptions

    @property
    def accept_rate(self) -> float:
        return self.stats.accept_rate

    @property
    def accepted_tok_per_tick(self) -> float:
        return self.stats.accepted_tok_per_tick

    @property
    def cobatch_ratio(self) -> float:
        return self.stats.cobatch_ratio

    @property
    def prefix_hit_rate(self) -> Optional[float]:
        return self.stats.prefix_hit_rate

    @property
    def cache_evictions(self) -> int:
        """Prefix-cache pressure evictions since the last ``reset_stats``
        (the pool counter is lifetime; benchmarks measure post-warmup)."""
        if self.pool.cache is None:
            return 0
        return self.pool.cache.evictions - self._evictions_base

    def metrics(self) -> dict:
        """Full telemetry snapshot — counters, derived rates, pool/router/
        cache/spec state, latency + tick distributions, SLO attainment.
        The stats line and the benchmark phases read this instead of
        engine internals; it also refreshes ``self.obs.registry``."""
        self.obs.collect(self)
        return self.obs.snapshot(self)

    def reset_stats(self) -> None:
        """Zero the serving counters without touching compile caches or the
        pool — benchmarks warm up on the engine they measure (a fresh Engine
        would also mean a fresh jit cache) and then discard the warmup's
        contribution here.  Telemetry (histograms, traces, timeline, SLO
        scores) resets with the counters."""
        self.stats.reset()
        if self.spec is not None:
            self.spec.draft_calls = 0
        if self.pool.cache is not None:
            self._evictions_base = self.pool.cache.evictions
        self.sched.preemptions = 0
        self.sched.finished.clear()
        self.obs.reset()

    # -- request intake ------------------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               arrival_time: float = 0.0, *,
               submodel_id: Optional[int] = None, session=None,
               ensemble: Optional[str] = None, slo_class: str = "default"
               ) -> Union[Request, EnsembleGroup]:
        """Queue one request.  With a ModelBank attached, the Router picks
        (or validates) the circuit; ``ensemble`` ("mean_logit" |
        "majority_vote") instead fans the prompt across ALL G circuits as
        one lockstep group and returns the EnsembleGroup.  ``slo_class``
        names the priority class the finished request is scored under
        (observability/slo.py)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 0 < len(prompt) <= self.ecfg.max_prompt_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, "
                f"{self.ecfg.max_prompt_len}] — an empty prompt has no "
                f"token to decode from (it would allocate zero pages and "
                f"decode off the null page)")
        mnt = min(max_new_tokens or self.ecfg.max_new_tokens,
                  self.ecfg.max_new_tokens)

        if ensemble is not None:
            if self.bank is None:
                raise ValueError("ensemble mode requires a ModelBank")
            if submodel_id is not None or session is not None:
                raise ValueError(
                    "ensemble fans across ALL circuits — submodel_id/"
                    "session routing hints conflict with it")
            if ensemble not in COMBINES:
                raise ValueError(
                    f"unknown combine {ensemble!r}; known: {COMBINES}")
            G = self.bank.num_submodels
            if G > self.ecfg.num_slots:
                raise ValueError(
                    f"ensemble needs {G} slots (one per circuit) but the "
                    f"engine has {self.ecfg.num_slots}")
            group = EnsembleGroup(id=self._next_group_id, combine=ensemble,
                                  share=self.ecfg.prefix_cache)
            self._next_group_id += 1
            # the shared prompt context [0, len - 1) is dense-parent
            # encoded (namespace b"dense"); each member's circuit engages
            # at the last prompt token — so the context bytes are
            # member-invariant and the leader can prefill them for all
            group.members = [
                Request(id=self._next_id + g, prompt=prompt,
                        max_new_tokens=mnt, arrival_time=arrival_time,
                        eos_id=self.ecfg.eos_id, submodel_id=g, group=group,
                        kv_namespace=b"dense", mask_from=len(prompt) - 1,
                        slo_class=slo_class)
                for g in range(G)]
            self._check_feasible(group.members[0])
            self._next_id += G
            if self.router is not None:
                for g in range(G):
                    self.router.acquire(g)
            for req in group.members:
                self.sched.submit(req)
                self.obs.on_submit(req, arrival_time)
            return group

        req = Request(id=self._next_id, prompt=prompt, max_new_tokens=mnt,
                      arrival_time=arrival_time, eos_id=self.ecfg.eos_id,
                      slo_class=slo_class)
        self._check_feasible(req)
        if self.bank is not None:
            req.submodel_id = self.router.route(
                submodel_id=submodel_id, session=session, prompt=prompt)
            req.kv_namespace = b"sub:%d" % req.submodel_id
        elif submodel_id not in (None, 0):
            raise ValueError("submodel routing requires a ModelBank")
        self._next_id += 1
        self.sched.submit(req)
        self.obs.on_submit(req, arrival_time)
        return req

    def _check_feasible(self, req: Request) -> None:
        """Reject requests that could never be admitted even into an empty
        pool — otherwise they'd pin the FCFS head and the drive loop would
        spin forever waiting for pages that cannot exist."""
        need = self._admission_need(req)
        if need > self.pool.capacity:
            raise ValueError(
                f"request needs {need} page(s) at admission "
                f"(policy={self.ecfg.policy}) but the pool has only "
                f"{self.pool.capacity}; raise num_pages or shrink "
                f"prompt/max_new_tokens")

    def _admission_need(self, req: Request) -> int:
        """Worst-case (no cache hit) pages the whole scheduling unit
        (solo, or every ensemble member) needs available to admit."""
        unit = req.group.members if req.group is not None else [req]
        return self.sched.unit_admission_pages(unit)

    # -- internals -----------------------------------------------------------
    def _chunk_bucket(self, n: int) -> int:
        """Power-of-two chunk-width bucket (bounds unified-step retraces)."""
        return pow2_bucket(n)

    def _sync_block_tables(self) -> None:
        """Incremental row sync of the device block table (see
        ``serving/block_table.py``)."""
        self.bt_rows_synced += self._bt.sync(
            self.pool, self.sched.running,
            lambda r: (r.id, r.admit_seq, self.pool.table_version(r.id)))

    def _sample_peak(self) -> None:
        self.peak_utilization = max(self.peak_utilization,
                                    self.pool.utilization())
        if self.bank is not None:
            for owner, util in self.pool.utilization_by_owner().items():
                if util > self.peak_util_by_submodel.get(owner, 0.0):
                    self.peak_util_by_submodel[owner] = util

    def _release(self, done: List[Request]) -> None:
        for req in done:
            # every finished request passes through here exactly once, on
            # every tick path (early returns and OOM raises included)
            self.obs.on_finish(req, req.t_done)
        if self.router is not None:
            for req in done:
                self.router.release(req.submodel_id)
        if self.spec is not None:
            for req in done:
                self.spec.drop(req.id)

    def _clock(self, now: Optional[float]) -> float:
        return time.monotonic() if now is None else now

    def _flush_copies(self, pairs: List[Tuple[int, int]]) -> None:
        """Issue the device-side page copies a COW swap requires, padded to
        a power-of-two width ((0, 0) pads copy the null page onto itself)
        so jit compiles one executable per bucket."""
        if not pairs:
            return
        n = self._chunk_bucket(len(pairs))
        src = np.zeros((n,), np.int32)
        dst = np.zeros((n,), np.int32)
        for i, (s, d) in enumerate(pairs):
            src[i], dst[i] = s, d
        self.cache = self._page_copy(self.cache, *marshal_i32(src, dst))
        self.cow_page_copies += len(pairs)

    def _prepare_entry_write(self, req: Request, start: int,
                             end: int) -> None:
        """Grow the request's table through ``end`` tokens and COW any page
        in the written range [start, end) that other tables (or the prefix
        cache) still hold.  May raise PagePoolOOM — the preempt-youngest
        loop in ``_plan_tick`` answers."""
        self.pool.ensure(req.id, end)
        self._flush_copies(self.pool.prepare_write(req.id, start, end))

    # -- tick planning -------------------------------------------------------
    def _plan_tick(self, now: float) -> Dict[int, _Entry]:
        """Fill the token budget: one decode token per decode-phase slot,
        then prompt chunks for prefill-phase slots in admission order.
        Preempts the youngest running sequence (and replans) whenever decode
        growth hits pool pressure; raises EngineOOM only when no preemption
        can help."""
        while True:
            try:
                return self._try_plan()
            except PagePoolOOM as e:
                victim = self.sched.preempt_youngest()
                if victim is None:
                    raise EngineOOM(
                        f"tick {self.steps}: {e}; no other sequence left to "
                        f"preempt — this request can never fit; raise "
                        f"--pages, lower --gen, or use --policy reserve"
                        ) from e
                unit = victim.group.members if victim.group is not None \
                    else [victim]
                for m in unit:
                    m.t_preempted = now
                    self.obs.on_preempt(m, now)
                    if self.spec is not None:
                        # the draft pool stays bounded by the running slots:
                        # a preempted request's draft KV is recomputed by
                        # one catch-up chunk on re-admission
                        self.spec.drop(m.id)

    def _try_plan(self) -> Dict[int, _Entry]:
        entries: Dict[int, _Entry] = {}
        budget = self.ecfg.token_budget
        decode, prefill = [], []
        for slot, req in sorted(self.sched.running.items()):
            (prefill if req.in_prefill else decode).append((slot, req))

        # speculative draft length for this tick: uniform across the
        # speculating slots (one verify-window width per call), sized so
        # the parent budget covers every decode slot's pending token plus
        # 1 + k verified tokens per speculating slot
        spec_k = self.ecfg.speculate_k if self.spec is not None else 0

        def allowance(r: Request) -> int:
            # a tick commits at most 1 + dl tokens, so drafting past the
            # request's remaining allowance minus one can never land (it
            # would only burn draft/verify budget and depress accept_rate
            # at every request tail).  The same bound keeps K/V writes
            # inside both max_model_len and the reserve-policy admission
            # reservation: the verify chunk ends at
            # context + dl <= prompt + max_new - 1
            return r.prompt_len + r.max_new_tokens - r.context_len - 1

        # only slots that can actually land a draft share the speculative
        # budget — a slot one token from its cap drafts nothing and must
        # not dilute the others' split
        n_spec = sum(1 for _, r in decode
                     if r.spec_eligible and allowance(r) > 0) \
            if spec_k else 0
        k_tick = min(speculative_draft_len(spec_k, budget, len(decode),
                                           n_spec), self.max_chunk - 1)
        for slot, req in decode:
            dl = 0
            if k_tick > 0 and req.spec_eligible:
                dl = max(0, min(k_tick, allowance(req)))
            # grows the table through context_len (+ the draft tail) and
            # COWs any shared page the writes would touch; may raise
            # PagePoolOOM
            self._prepare_entry_write(req, req.context_len - 1,
                                      req.context_len + dl)
            toks = np.zeros((1 + dl,), np.int32)
            toks[0] = req.out_tokens[-1]     # drafts land in toks[1:] later
            entries[slot] = _Entry(
                req=req, start=req.context_len - 1, tokens=toks,
                chunk_len=1 + dl, sample_step=len(req.out_tokens),
                record=True, mask_id=req.submodel_id, draft_len=dl)
            budget -= 1 + dl
        # prompt chunks soak up whatever budget the decode tokens left,
        # oldest admission first (it holds pages; finish it soonest).
        # Ensemble groups advance in LOCKSTEP: every member gets the same
        # chunk width this tick (identical streams + identical prefill_pos),
        # so all members finish prefill in the same tick and their combined
        # logits produce the group's first token together.  Chunks break at
        # ``mask_from``: an ensemble stream is dense-parent encoded before
        # it (shared context) and member-masked from it on — in share mode
        # only the leader computes the dense region, then the group forks.
        prefill.sort(key=lambda sr: sr[1].admit_seq)
        planned_groups = set()
        for slot, req in prefill:
            group = req.group
            if group is not None:
                if group.id in planned_groups:
                    continue
                planned_groups.add(group.id)
                if group.share and not group.forked:
                    leader = group.leader
                    if leader.prefill_pos < leader.mask_from:
                        unit = [(leader.slot, leader)]   # dense solo advance
                    else:
                        self.prefill_tok_saved += self.sched.fork_group(group)
                        unit = [(m.slot, m) for m in group.members]
                else:
                    unit = [(m.slot, m) for m in group.members]
            else:
                unit = [(slot, req)]
            n = len(unit)
            r0 = unit[0][1]
            want = len(r0.kv_tokens) - r0.prefill_pos
            dense = r0.prefill_pos < r0.mask_from
            if dense:                       # stop at the mask boundary
                want = min(want, r0.mask_from - r0.prefill_pos)
            cl = min(want, max(budget, 0) // n, self.max_chunk)
            if cl <= 0:
                continue                          # budget exhausted this tick
            # write-prep members BEFORE the leader: each member's COW of the
            # shared boundary page redeems its own deferred-reserve credit,
            # and the leader — whose admission reserve covers the original
            # page — is the last holder left and writes it in place.
            # Leader-first would draw an unreserved free page for the
            # leader's copy while a member credit idles, OOMing a pool
            # sized exactly to the reserve-policy worst case.
            for s, r in unit[1:] + unit[:1]:
                kv = r.kv_tokens
                finishes = r.prefill_pos + cl == len(kv)
                self._prepare_entry_write(r, r.prefill_pos, r.prefill_pos + cl)
                entries[s] = _Entry(
                    req=r, start=r.prefill_pos,
                    tokens=kv[r.prefill_pos:r.prefill_pos + cl],
                    chunk_len=cl, sample_step=0,
                    # the chunk that completes a *fresh* prompt yields the
                    # first token; a preempted request's next token is
                    # already known
                    record=finishes and not r.out_tokens,
                    mask_id=self._dense_mask_id if dense else r.submodel_id)
            budget -= cl * n
        return entries

    # -- one engine tick -----------------------------------------------------
    def step(self, now: Optional[float] = None,
             tick_clock=None) -> List[Request]:
        """Admit + advance every running slot by one unified device call.
        Returns the requests that finished this tick.  Pass ``tick_clock``
        (a zero-arg callable on the same epoch as ``now``) for an honest
        post-tick timestamp; without it every event in the tick shares
        ``now``."""
        now = self._clock(now)
        tick_now = tick_clock if tick_clock else (lambda: now)
        pc = time.perf_counter                    # timeline clock (µs spans)
        m_start = pc()
        for req in self.sched.admit(now):
            self.cache_hit_tokens += req.num_cached_tokens
            self.cache_eligible_tokens += req.cache_eligible_tokens
            self.prefill_tok_saved += req.num_cached_tokens
            self.obs.on_admit(req, now)
        self._sample_peak()                       # admissions allocate pages
        done = self.sched.evict_finished(tick_now())  # e.g. max_new_tokens==1
        if not self.sched.running:
            if self.sched.waiting:
                # a preempted request's context can outgrow the whole pool;
                # with nothing running and the FCFS head unadmittable even
                # into an empty pool, the drive loop would spin forever
                head = self.sched.waiting[0]
                need = self._admission_need(head)
                if need > self.pool.capacity:
                    self._release(done)   # don't leak router loads on raise
                    raise EngineOOM(
                        f"request {head.id} needs {need} page(s) to "
                        f"re-admit but the pool has only "
                        f"{self.pool.capacity}; its context can never "
                        f"fit — raise --pages or lower --gen")
            self._release(done)
            return done

        try:
            entries = self._plan_tick(now)
        except EngineOOM:
            self._release(done)           # don't leak router loads on raise
            raise
        self._sample_peak()                       # decode growth allocates too
        if not entries:                           # nothing runnable this tick
            self._release(done)
            return done
        m_plan = pc()

        # draft proposals first: one jitted draft-circuit call covering
        # every speculating slot (catch-up chunk + on-device scan), then
        # the drafted tokens ride the verify chunks of the parent call
        spec_units = [(slot, e) for slot, e in entries.items()
                      if e.draft_len > 0]
        draft_span = ()
        if spec_units:
            t_draft = pc()
            k_tick = max(e.draft_len for _, e in spec_units)
            drafts, draft_probs = self.spec.propose(
                [(s, e.req) for s, e in spec_units], k_tick, self._root_key)
            for slot, e in spec_units:
                e.tokens[1:1 + e.draft_len] = drafts[slot, :e.draft_len]
            draft_span = (("draft", t_draft, pc()),)
        else:
            draft_probs = self._noprobs

        B = self.ecfg.num_slots
        C = self._chunk_bucket(max(e.chunk_len for e in entries.values()))
        tokens = np.zeros((B, C), np.int32)
        starts = np.zeros((B,), np.int32)
        chunk_lens = np.zeros((B,), np.int32)
        req_ids = np.zeros((B,), np.int32)
        sample_steps = np.zeros((B,), np.int32)
        submodel_ids = np.zeros((B,), np.int32)
        seg_ids = np.arange(B, dtype=np.int32)    # solo: own segment
        vote_flags = np.zeros((B,), bool)
        draft_lens = np.zeros((B,), np.int32)
        for slot, e in entries.items():
            tokens[slot, :e.chunk_len] = e.tokens
            starts[slot] = e.start
            chunk_lens[slot] = e.chunk_len
            req_ids[slot] = e.req.id
            sample_steps[slot] = e.sample_step
            submodel_ids[slot] = e.mask_id
            draft_lens[slot] = e.draft_len
            group = e.req.group
            if group is not None:
                seg_ids[slot] = group.leader.slot
                if group.combine == "majority_vote":
                    vote_flags[slot] = True       # members sample, then vote
                else:
                    # mean-logit: one sampling key per group -> one draw
                    req_ids[slot] = group.leader.id

        self.ticks_nonempty += 1
        if len({e.req.submodel_id for e in entries.values()}) > 1:
            self.ticks_cobatched += 1
        self._sync_block_tables()

        # ticks without an ensemble group skip the on-device combine
        # entirely (static jit arg: one extra compile per bucket at most)
        ensembles = any(e.req.group is not None for e in entries.values())
        m_host = pc()
        (d_tokens, d_starts, d_chunk_lens, d_req_ids, d_sample_steps,
         d_submodel_ids, d_seg_ids, d_vote_flags, d_draft_lens) = \
            marshal_i32(tokens, starts, chunk_lens, req_ids, sample_steps,
                        submodel_ids, seg_ids, vote_flags, draft_lens)
        sampled, accepted, self.cache = self._step(
            self.params, self.cache, d_tokens, d_starts, d_chunk_lens,
            self._bt.dev, d_req_ids, d_sample_steps, d_submodel_ids,
            d_seg_ids, d_vote_flags, d_draft_lens, draft_probs,
            self._root_key, ensembles=ensembles)
        # one deliberate host pull commits the tick: both outputs in a
        # single transfer instead of two sequential np.asarray blocks
        sampled, accepted = \
            jax.device_get((sampled, accepted))   # hornlint: sync-ok
        m_dev = pc()
        self.steps += 1
        post = tick_now()

        for slot, e in entries.items():
            req = e.req
            was_prefill = req.in_prefill
            if was_prefill:
                self.prefill_tokens += e.chunk_len
                self.obs.on_prefill_chunk(req, post, e.start, e.chunk_len)
            if e.draft_len:
                self._commit_spec(slot, e, int(sampled[slot]),
                                  int(accepted[slot]), post)
                continue
            # decode writes K/V too (position context_len - 1), so advance
            # prefill_pos past every write this tick — otherwise the next
            # generated token flips the request back into "prefill" and
            # re-feeds one already-written token as a redundant chunk
            req.prefill_pos = max(req.prefill_pos, e.start + e.chunk_len)
            if was_prefill and req.page_hashes:
                # content-index every freshly materialized full page of the
                # publishable (namespace-uniform) region — the next request
                # with this prefix maps the pages instead of recomputing
                full = min(req.prefill_pos, req.publishable_end) \
                    // self.ecfg.page_size
                if full:
                    self.pool.publish_prefix(req.id, req.page_hashes, full)
            if e.record:
                self.sched.record_token(slot, int(sampled[slot]), post)
                self.generated_tokens += 1
                sid = req.submodel_id
                self.tokens_by_submodel[sid] = \
                    self.tokens_by_submodel.get(sid, 0) + 1
                self.obs.on_token(req, post)

        finished = self.sched.evict_finished(post)
        self._release(done + finished)
        # the tick's phase spans + per-slot device-window annotations —
        # per-slot tuples are only built when a timeline is recording
        if self.obs.timeline is not None:
            slot_events = [
                (slot, f"verify+{e.draft_len}" if e.draft_len
                 else ("decode" if e.sample_step else "prefill"),
                 m_host, m_dev,
                 {"req": e.req.id, "tokens": e.chunk_len, "start": e.start})
                for slot, e in entries.items()]
            counters = {"used_pages": self.pool.used_pages,
                        "cached_pages": self.pool.cached_pages,
                        "running": len(self.sched.running),
                        "waiting": len(self.sched.waiting)}
        else:
            slot_events, counters = (), None
        # estimated KV HBM traffic of this tick's device call (roofline
        # gauges): every live slot's paged attention walks its whole
        # table each layer
        kv_read_bytes = self._kv_bytes_per_page * sum(
            self.pool.pages_for(e.req.context_len)
            for e in entries.values())
        self.obs.on_tick(self.steps - 1, (m_start, m_plan, m_host, m_dev,
                                          pc()),
                         slot_events=slot_events, extra_spans=draft_span,
                         counters=counters,
                         tokens=int(sum(e.chunk_len
                                        for e in entries.values())),
                         t=post, used_pages=self.pool.used_pages,
                         live_pages=self.pool.live_table_pages,
                         kv_read_bytes=kv_read_bytes)
        return done + finished

    def _commit_spec(self, slot: int, e: _Entry, sampled: int, acc: int,
                     now: float) -> None:
        """Land a verify verdict: commit the accepted draft prefix plus
        the one verified (bonus or correction) token the parent sampled
        after it — stopping at EOS / max_new exactly where sequential
        decode would — then roll the page tail back to the committed K/V
        (a ref-release via ``truncate_seq``, never a copy) and tell the
        draft runner which of its proposals survived."""
        req = e.req
        acc = min(acc, e.draft_len)
        n0 = req.context_len                  # before any commit
        commit = [int(t) for t in e.tokens[1:1 + acc]] + [sampled]
        c = 0
        for tok in commit:
            self.sched.record_token(slot, tok, now)
            c += 1
            self.generated_tokens += 1
            sid = req.submodel_id
            self.tokens_by_submodel[sid] = \
                self.tokens_by_submodel.get(sid, 0) + 1
            if req.finished:                  # EOS or max_new mid-window
                break
        self.spec_slot_ticks += 1
        self.spec_drafted += e.draft_len
        self.spec_accepted += min(acc, c)
        self.spec_committed += c
        self.obs.on_speculate(req, now, e.draft_len, min(acc, c), c)
        self.obs.on_token(req, now, n=c)
        if req.finished:
            # pages are freed wholesale by evict_finished and the draft
            # state by _release; prefill_pos only needs to stay consistent
            req.prefill_pos = n0 + min(acc, c)
            return
        # valid K/V = committed stream minus its pending last token: the
        # context plus exactly the accepted drafts (the verify chunk wrote
        # K/V for every draft; the rejected tail is stale and its pages go
        # back — recredited under reserve so the admission-time
        # reservation survives the rollback)
        req.prefill_pos = n0 + acc
        self.pool.truncate_seq(req.id, req.prefill_pos,
                               recredit=self.ecfg.policy == "reserve")
        self.spec.commit(req, acc)

    def finished_streams(self) -> List[Request]:
        """Finished requests deduplicated to one per delivered token
        stream: solo requests plus one leader per ensemble group (every
        member carries the identical combined stream).  User-facing
        latency/throughput accounting should use this; device-side token
        counts still sum over all of ``sched.finished``."""
        return [r for r in self.sched.finished
                if r.group is None or r is r.group.leader]

    def run(self, *, clock=None) -> List[Request]:
        """Drive until every submitted request has finished."""
        clock = clock or time.monotonic
        while self.sched.has_work():
            self.step(clock())
        return self.sched.finished


def _stats_attr(name: str) -> property:
    def get(self):
        return getattr(self.stats, name)

    def set_(self, v):
        setattr(self.stats, name, v)

    return property(get, set_)


# every EngineStats counter stays a plain engine attribute
# (``engine.generated_tokens``, ``self.steps += 1``) — derived from the
# dataclass fields, so a counter added to EngineStats is automatically an
# engine attribute too
for _f in dataclasses.fields(EngineStats):
    setattr(Engine, _f.name, _stats_attr(_f.name))
del _f
