"""ModelBank: G fixed Horn sub-models ("parallel circuits") of one parent.

Horn trains disconnected sub-models that share the parent's weights (paper
§2); this module is the *serving-side* registry of those circuits.  Each
sub-model is a fixed, deterministic draw of per-layer block masks over the
axes ``core/submodel.plan`` names (FFN hidden units, MoE expert hidden
units, optional attention heads, optional embedding channels) — the same
``group_block_mask`` the trainer uses, but drawn ONCE per bank (seeded),
not per step.  All G circuits share:

  * one parent parameter pytree (masks select each circuit's subnetwork);
  * one device page pool — per-slot masks are gathered by ``submodel_id``
    *inside* the jitted unified serving step, so tokens from different
    circuits co-batch in the same tick.

Masks are stored as {0., 1.} (NOT inverted-dropout 1/keep): a served
circuit is the paper's materializable sub-model, and ``materialize`` must
produce byte-equivalent logits from physically smaller weights — the train
-time 1/keep scale is a variance correction for the stochastic ensemble,
not part of any one circuit.

``materialize`` realizes the paper's memory claim for deployment: a
keep-0.5 circuit's FFN weights exported at roughly half size (zero-padded
to the widest layer so scanned superblocks keep one stacked shape).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HornConfig, ModelConfig
from repro.core import submodel as SM
from repro.core.parallel_dropout import expand_units, group_block_mask

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class DraftModel:
    """A materialized circuit packaged as a speculative-decoding draft:
    physically smaller standalone weights whose forward is logit-equivalent
    to the masked parent forward of ``circuit`` — the cheap proposer the
    dense parent verifies against (ROADMAP: "speculative small-circuit
    drafting for the dense parent")."""
    cfg: ModelConfig
    params: dict
    circuit: int                        # bank circuit id it was cut from
    kept_frac: float                    # mean FFN keep fraction (reporting)

# plan() axis name -> serve-mask key consumed by transformer.lm_forward
_AXIS_KEY = {"ffn_hidden": "ffn", "moe_hidden": "moe",
             "attn_heads": "heads", "input_embed": "input"}
# mask keys whose draw is independent per layer (vs. one draw for the bank)
_PER_LAYER = {"ffn", "moe", "heads"}


def _expand_blocks(mb: np.ndarray, units: int) -> np.ndarray:
    """[G, n_blocks] {0,1} block mask -> [G, units] unit mask, through the
    SAME block->unit rule the train-time masks use (one source of truth in
    ``parallel_dropout.expand_units``)."""
    return np.asarray(expand_units(jnp.asarray(mb), units))


class ModelBank:
    """G sub-models of one parent, addressable by ``submodel_id`` in
    ``[0, num_submodels)``.  ``masks`` maps serve-mask keys to binary
    arrays: "input" [G, d_model]; "ffn" [G, L, d_ff]; "moe" [G, L, moe_ff];
    "heads" [G, L, H] — only the axes the Horn config actually masks exist.
    """

    def __init__(self, cfg: ModelConfig, horn: HornConfig,
                 num_submodels: int, *, seed: int = 0):
        if num_submodels < 1:
            raise ValueError("need at least one submodel")
        if cfg.ssm_state:
            raise ValueError(
                "ModelBank serves attention LMs (SSM channel masks are "
                "train-only; paged serving rejects SSM mixers anyway)")
        self.cfg, self.horn, self.seed = cfg, horn, seed
        self.num_submodels = num_submodels
        self.masks: Dict[str, np.ndarray] = {}
        self._device: Optional[Dict[str, jnp.ndarray]] = None

        G, L = num_submodels, cfg.num_layers
        base = jax.random.fold_in(jax.random.key(seed), horn.seed_salt)
        for ai, axis in enumerate(SM.plan(cfg, horn)):
            key = _AXIS_KEY.get(axis.name)
            if key is None or axis.keep >= 1.0:
                continue
            k_ax = jax.random.fold_in(base, ai)
            if key in _PER_LAYER:
                rows = [_expand_blocks(
                    np.asarray(group_block_mask(
                        jax.random.fold_in(k_ax, li), G, axis.units,
                        axis.keep, axis.block_size)) > 0, axis.units)
                    for li in range(L)]
                self.masks[key] = np.stack(rows, axis=1).astype(np.float32)
            else:
                mb = np.asarray(group_block_mask(
                    k_ax, G, axis.units, axis.keep, axis.block_size)) > 0
                self.masks[key] = _expand_blocks(
                    mb, axis.units).astype(np.float32)
        if not self.masks:
            raise ValueError(
                "bank has no masked axes (every keep rate >= 1.0) — G "
                "identical dense circuits; lower keep_hidden/keep_input")

    # -- serving ------------------------------------------------------------
    def device_masks(self) -> Dict[str, jnp.ndarray]:
        """The mask tensors the unified step gathers per slot (f32 on
        device, cached).  Never empty: __init__ rejects a bank with no
        masked axis.

        Row ``num_submodels`` (one past the last circuit) is the all-ones
        *dense sentinel*: gathering it runs the unmasked parent.  The
        engine uses it to encode an ensemble's shared prompt context —
        positions [0, prompt_len - 1) are parent-encoded, so their K/V is
        byte-identical across members and one prefill (or one prefix-cache
        entry) serves all G circuits."""
        if self._device is None:
            self._device = {
                k: jnp.concatenate(
                    [jnp.asarray(v, f32),
                     jnp.ones((1,) + v.shape[1:], f32)], axis=0)
                for k, v in self.masks.items()}
        return self._device

    def subset(self, ids: Sequence[int]) -> "ModelBank":
        """A bank view holding only ``ids`` (same mask rows, re-indexed
        from 0) — e.g. ``bank.subset([g])`` builds the dedicated one-model
        bank the routed-parity tests compare against."""
        sub = object.__new__(ModelBank)
        sub.cfg, sub.horn, sub.seed = self.cfg, self.horn, self.seed
        sub.num_submodels = len(ids)
        sub.masks = {k: v[np.asarray(ids)] for k, v in self.masks.items()}
        sub._device = None
        return sub

    # -- export (paper's memory-reduction claim) ----------------------------
    def materialize(self, g: int, params) -> Tuple[ModelConfig, dict]:
        """Extract circuit ``g`` as a standalone model with *physically
        smaller* FFN weights: (small_cfg, small_params) whose forward is
        logit-equivalent to the masked parent forward of submodel ``g``.

        FFN-only by construction — a bank that also masks embedding
        channels or attention heads cannot be shrunk this way (those masks
        keep the tensor shapes), so it is rejected rather than silently
        exporting the wrong model.  Per-layer live counts differ, so every
        layer is zero-padded to the widest kept width (exact: see
        ``submodel.materialize_units``) and the scanned superblock keeps
        one stacked shape.
        """
        if not 0 <= g < self.num_submodels:
            raise ValueError(f"submodel {g} not in bank of "
                             f"{self.num_submodels}")
        extra = set(self.masks) - {"ffn"}
        if extra:
            raise ValueError(
                f"materialize is FFN-only; bank also masks {sorted(extra)}")
        if "ffn" not in self.masks:
            raise ValueError("bank has no FFN masks (keep_hidden >= 1?)")
        cfg = self.cfg
        if any(cfg.layer_is_moe(i) for i in range(cfg.num_layers)):
            raise ValueError("materialize does not support MoE layers")

        rows = self.masks["ffn"][g]                     # [L, d_ff]
        ffk = int(max((row > 0).sum() for row in rows))
        new_params = jax.tree.map(lambda x: x, params)  # fresh containers
        pat = cfg.layer_pattern
        R = cfg.pattern_repeats
        if R:
            for i in range(len(pat)):
                bp = new_params["blocks"][f"l{i}"]
                per_r = [SM.materialize_units(
                    {k: w[r] for k, w in bp["mlp"].items()},
                    rows[r * len(pat) + i], pad_to=ffk)
                    for r in range(R)]
                bp["mlp"] = {k: jnp.stack([m[k] for m in per_r])
                             for k in per_r[0]}
        for i in range(cfg.pattern_remainder):
            rp = new_params["rem"][f"r{i}"]
            rp["mlp"] = SM.materialize_units(
                rp["mlp"], rows[R * len(pat) + i], pad_to=ffk)
        small_cfg = dataclasses.replace(cfg, d_ff=ffk,
                                        name=f"{cfg.name}-sub{g}")
        return small_cfg, new_params

    def draft_model(self, g: int, params) -> DraftModel:
        """Package circuit ``g`` as a speculative-decoding draft.

        Draft-circuit guidance: acceptance tracks how often the circuit's
        next-token distribution agrees with the verifier's, so prefer the
        highest-keep circuit you can afford to run — a Horn-trained
        keep-0.5 circuit is distilled toward the parent and accepts well,
        while an *untrained* parent needs a high-keep draft (the shared
        attention + embedding path dominates agreement; every dropped FFN
        block decorrelates the argmax a little)."""
        cfg, p = self.materialize(g, params)
        return DraftModel(cfg, p, g,
                          float((self.masks["ffn"][g] > 0).mean()))

    # -- reporting ----------------------------------------------------------
    def kept_fractions(self) -> Dict[str, List[float]]:
        """Per-submodel mean kept fraction per masked axis (bench/report)."""
        return {k: [float((v[g] > 0).mean())
                    for g in range(self.num_submodels)]
                for k, v in self.masks.items()}
