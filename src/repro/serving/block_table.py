"""Host->device block-table mirror with incremental row sync.

Both paged engines — the parent serving engine and the speculative draft
runner — keep the device-resident block table their jitted step reads in
sync with a host mirror, re-uploading only the ROWS whose page sets
changed since the last device call (new pages appended/adopted, COW or
rollback swaps, slot re-assigned, slot vacated).  Steady decode within a
page uploads nothing and reuses the same device array.  One
implementation serves both so the dirtiness scheme can never drift
between the two tables; what counts as "changed" is the caller's
``state_key`` (the engine folds in ``admit_seq`` so a preempt/re-admit
cycle landing the same request back in its old slot still re-syncs; the
draft runner needs only (id, table version))."""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.kernel import NULL_PAGE


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (jit compile-cell bucketing)."""
    return 1 << max(0, int(n - 1).bit_length())


def marshal_i32(*arrays) -> tuple:
    """Upload host arrays as device operands for a jitted step.

    The single choke point for host->device argument marshalling: integer
    operands get an explicit int32 (no accidental int64 weak types
    changing the compile-cell signature between ticks), bool/float
    operands keep their dtype, and the hornlint host-sync pass checks one
    helper instead of N inline ``jnp.asarray`` blocks."""
    out = []
    for a in arrays:
        arr = np.asarray(a)
        dtype = jnp.int32 if arr.dtype.kind in ("i", "u") else None
        out.append(jnp.asarray(arr, dtype))
    return tuple(out)


class BlockTableMirror:
    """[num_slots, max_pages] int32 device table + host mirror + per-slot
    dirtiness state.  ``rows_synced`` counts lifetime row uploads."""

    def __init__(self, num_slots: int, max_pages_per_seq: int):
        self.host = np.zeros((num_slots, max_pages_per_seq), np.int32)
        self.dev = jnp.asarray(self.host)
        self._state: List[Optional[tuple]] = [None] * num_slots
        self.rows_synced = 0

    def sync(self, pool, active: Dict[int, object],
             state_key: Callable[[object], tuple]) -> int:
        """Re-upload the rows whose ``state_key`` changed.  ``active``
        maps slot -> request (a vacated slot's row resets to the null
        page); ``state_key(req)`` must include the pool's table version
        so any table mutation dirties the row.  Returns rows uploaded."""
        dirty: List[int] = []
        for slot in range(len(self._state)):
            req = active.get(slot)
            if req is None:
                if self._state[slot] is not None:
                    self.host[slot] = NULL_PAGE   # vacated row
                    self._state[slot] = None
                    dirty.append(slot)
                continue
            state = state_key(req)
            if self._state[slot] == state:
                continue
            table = pool.table(req.id)
            row = self.host[slot]
            row[:] = NULL_PAGE
            row[:len(table)] = table
            self._state[slot] = state
            dirty.append(slot)
        if dirty:
            idx = np.asarray(dirty, np.int32)
            self.dev = self.dev.at[jnp.asarray(idx)].set(
                jnp.asarray(self.host[idx]))
            self.rows_synced += len(dirty)
        return len(dirty)
