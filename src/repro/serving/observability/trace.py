"""Request lifecycle tracing and the per-tick engine timeline.

Two recorders, two clocks, deliberately:

  * ``RequestTracer`` records typed lifecycle events (submit -> admit /
    prefix-adopt -> prefill chunk(s) -> token commits -> speculate ->
    preempt -> finish) on the **engine clock** — the same ``now`` /
    ``arrival_time`` values the scheduler stamps onto requests.  TTFT,
    time-in-queue, preemption wait, and accept rate are therefore
    *derived* from events and match the request-timestamp ground truth
    exactly (tested), instead of being hand-computed in three places.
  * ``TickTimeline`` records wall spans on ``time.perf_counter``: each
    engine tick split into plan / host_prep / device_step / commit
    phases, one annotated span per slot per device call, plus instant
    markers (admissions, preemptions) and counter tracks (pool pages,
    queue depth).  ``to_chrome()`` emits Chrome Trace Event JSON —
    ``--trace-out trace.json`` opens directly in Perfetto
    (https://ui.perfetto.dev) or chrome://tracing.

Everything here is host-side and append-only; the jitted step never
sees any of it, so the one-device-call-per-tick invariant is untouched.
"""
from __future__ import annotations

import json
import numbers
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

# -- lifecycle event kinds ---------------------------------------------------
SUBMIT = "submit"                # queued (t = arrival_time)
ADMIT = "admit"                  # joined a slot (data: slot, cached, wait_s)
PREFIX_ADOPT = "prefix_adopt"    # admission mapped cached pages (data: tokens)
PREFILL_CHUNK = "prefill_chunk"  # chunk streamed into pages (data: start, n)
TOKEN = "token"                  # committed tokens (data: n)
SPECULATE = "speculate"          # verify verdict (data: drafted, accepted, n)
PREEMPT = "preempt"              # evicted back to the queue head
FINISH = "finish"                # stream complete (EOS / max_new)

EVENT_KINDS = (SUBMIT, ADMIT, PREFIX_ADOPT, PREFILL_CHUNK, TOKEN,
               SPECULATE, PREEMPT, FINISH)


@dataclass
class TraceEvent:
    kind: str
    t: float                         # engine-clock seconds
    data: dict = field(default_factory=dict)


@dataclass
class RequestTrace:
    """One request's event stream plus the derived lifecycle metrics.

    Derivations only ever read events — if a derived number disagrees
    with the scheduler's own timestamps, the *trace* is wrong, which is
    exactly what the parity test pins down."""

    req_id: int
    events: List[TraceEvent] = field(default_factory=list)

    def add(self, kind: str, t: float, **data) -> None:
        self.events.append(TraceEvent(kind, t, data))

    def first(self, kind: str) -> Optional[TraceEvent]:
        for e in self.events:
            if e.kind == kind:
                return e
        return None

    def of_kind(self, kind: str) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == kind]

    # -- derived metrics -----------------------------------------------------
    @property
    def submit_t(self) -> Optional[float]:
        e = self.first(SUBMIT)
        return e.t if e else None

    @property
    def first_token_t(self) -> Optional[float]:
        e = self.first(TOKEN)
        return e.t if e else None

    @property
    def finish_t(self) -> Optional[float]:
        e = self.first(FINISH)
        return e.t if e else None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.submit_t is None or self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def latency_s(self) -> Optional[float]:
        if self.submit_t is None or self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    @property
    def queue_s(self) -> Optional[float]:
        """Submit -> first admission."""
        adm = self.first(ADMIT)
        if adm is None or self.submit_t is None:
            return None
        return adm.t - self.submit_t

    @property
    def num_preemptions(self) -> int:
        return len(self.of_kind(PREEMPT))

    @property
    def preempt_wait_s(self) -> float:
        """Total time spent back in the queue after preemptions: the sum
        over each preempt -> next re-admission gap (the queueing cost a
        preemption injects; the recompute cost shows up as extra
        ``prefill_chunk`` tokens)."""
        total, pending = 0.0, None
        for e in self.events:
            if e.kind == PREEMPT:
                pending = e.t
            elif e.kind == ADMIT and pending is not None:
                total += e.t - pending
                pending = None
        return total

    @property
    def prefill_tokens(self) -> int:
        return sum(e.data.get("n", 0) for e in self.of_kind(PREFILL_CHUNK))

    @property
    def cached_tokens(self) -> int:
        return sum(e.data.get("n", 0) for e in self.of_kind(PREFIX_ADOPT))

    @property
    def committed_tokens(self) -> int:
        return sum(e.data.get("n", 0) for e in self.of_kind(TOKEN))

    @property
    def drafted_tokens(self) -> int:
        return sum(e.data.get("drafted", 0) for e in self.of_kind(SPECULATE))

    @property
    def accepted_tokens(self) -> int:
        return sum(e.data.get("accepted", 0) for e in self.of_kind(SPECULATE))


class RequestTracer:
    """Lifecycle recorder: one ``RequestTrace`` per request id, moved to
    the ``finished`` ring on its finish event.  ``maxlen`` bounds
    retention for long-running servers (None keeps everything — the
    launcher and tests read the full set at exit)."""

    def __init__(self, maxlen: Optional[int] = None):
        self.live: Dict[int, RequestTrace] = {}
        self.finished: Deque[RequestTrace] = deque(maxlen=maxlen)

    def record(self, req_id: int, kind: str, t: float, **data) -> None:
        tr = self.live.get(req_id)
        if tr is None:
            tr = self.live[req_id] = RequestTrace(req_id)
        tr.add(kind, t, **data)
        if kind == FINISH:
            self.finished.append(self.live.pop(req_id))

    def get(self, req_id: int) -> Optional[RequestTrace]:
        if req_id in self.live:
            return self.live[req_id]
        for tr in self.finished:
            if tr.req_id == req_id:
                return tr
        return None

    @property
    def num_events(self) -> int:
        return sum(len(t.events) for t in self.live.values()) \
            + sum(len(t.events) for t in self.finished)

    def clear(self) -> None:
        self.live.clear()
        self.finished.clear()


# -- per-tick engine timeline ------------------------------------------------
TICK_PHASES = ("plan", "host_prep", "device_step", "commit")

_PID = 0           # one engine process
_ENGINE_TID = 0    # engine-phases track; slot s renders on tid s + 1


class TickTimeline:
    """Wall-clock spans per engine tick, exported as Chrome Trace Event
    JSON.  Tracks: tid 0 is the engine-phases track (plan / host_prep /
    device_step / commit slices per tick, nested extra spans like the
    draft call, instants, counter series); tid ``s + 1`` is slot ``s``
    (what that slot contributed to each device call: ``prefill``,
    ``decode``, or ``verify+K``, annotated with request id and token
    counts).  Timestamps are ``time.perf_counter`` microseconds,
    rebased to the first recorded event at export."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self._spans: List[Tuple[str, int, float, float, dict]] = []
        self._instants: List[Tuple[str, float, dict]] = []
        self._counters: List[Tuple[str, float, dict]] = []
        self._metadata: dict = {}
        self.ticks = 0

    def set_metadata(self, **kv) -> None:
        """Stamp run-level configuration (kv_dtype, pages_per_step,
        speculate_k, ...) into the export: it lands both in
        ``otherData`` and as an ``engine_config`` metadata event, so two
        traces from differently-tuned engines are distinguishable inside
        Perfetto, not just by filename."""
        self._metadata.update(kv)

    # -- recording -----------------------------------------------------------
    def add_tick(self, tick: int, marks: Sequence[float],
                 slot_events: Sequence[Tuple[int, str, float, float, dict]]
                 = (), extra_spans: Sequence[Tuple[str, float, float]] = (),
                 counters: Optional[dict] = None) -> None:
        """``marks`` are the 5 phase boundaries (start, after-plan,
        after-host-prep, after-device, end); ``slot_events`` are
        (slot, name, t0, t1, args) annotations; ``extra_spans`` nest
        inside the tick on the engine track (e.g. the draft call);
        ``counters`` is a point sample for the counter track."""
        if len(marks) != len(TICK_PHASES) + 1:
            raise ValueError(
                f"need {len(TICK_PHASES) + 1} marks, got {len(marks)}")
        for name, t0, t1 in zip(TICK_PHASES, marks, marks[1:]):
            self._spans.append((name, _ENGINE_TID, t0, t1, {"tick": tick}))
        for name, t0, t1 in extra_spans:
            self._spans.append((name, _ENGINE_TID, t0, t1, {"tick": tick}))
        for slot, name, t0, t1, args in slot_events:
            self._spans.append((name, slot + 1, t0, t1,
                                {"tick": tick, **args}))
        if counters:
            self._counters.append(("engine", marks[0], dict(counters)))
        self.ticks += 1

    def span(self, name: str, t0: float, t1: float,
             tid: int = _ENGINE_TID, **args) -> None:
        """One standalone wall span on the given track — compile events
        and other out-of-tick work the phase marks don't cover."""
        self._spans.append((name, tid, t0, t1, args))

    def instant(self, name: str, t: Optional[float] = None,
                **args) -> None:
        self._instants.append((name, self.clock() if t is None else t, args))

    @property
    def num_events(self) -> int:
        return len(self._spans) + len(self._instants) + len(self._counters)

    def clear(self) -> None:
        self._spans.clear()
        self._instants.clear()
        self._counters.clear()
        self.ticks = 0

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome Trace Event JSON (the ``traceEvents`` object form) —
        loadable as-is in Perfetto / chrome://tracing."""
        times = [t0 for _, _, t0, _, _ in self._spans] \
            + [t for _, t, _ in self._instants] \
            + [t for _, t, _ in self._counters]
        t0 = min(times) if times else 0.0
        us = lambda t: (t - t0) * 1e6               # noqa: E731
        tids = sorted({tid for _, tid, _, _, _ in self._spans})
        ev: List[dict] = [{
            "ph": "M", "pid": _PID, "tid": _ENGINE_TID,
            "name": "process_name", "args": {"name": "horn-serving-engine"},
        }]
        if self._metadata:
            ev.append({"ph": "M", "pid": _PID, "tid": _ENGINE_TID,
                       "name": "engine_config",
                       "args": dict(self._metadata)})
        for tid in sorted(set(tids) | {_ENGINE_TID}):
            ev.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name",
                       "args": {"name": "engine phases" if tid == 0
                                else f"slot {tid - 1}"}})
        for name, tid, a, b, args in self._spans:
            ev.append({"ph": "X", "pid": _PID, "tid": tid, "name": name,
                       "cat": "engine" if tid == _ENGINE_TID else "slot",
                       "ts": us(a), "dur": max(0.0, us(b) - us(a)),
                       "args": args})
        for name, t, args in self._instants:
            ev.append({"ph": "i", "pid": _PID, "tid": _ENGINE_TID,
                       "name": name, "cat": "engine", "ts": us(t),
                       "s": "t", "args": args})
        for name, t, values in self._counters:
            ev.append({"ph": "C", "pid": _PID, "tid": _ENGINE_TID,
                       "name": name, "ts": us(t), "args": values})
        other = {"source": "repro.serving.observability"}
        if self._metadata:
            other["engine_config"] = dict(self._metadata)
        return {"traceEvents": ev,
                "displayTimeUnit": "ms",
                "otherData": other}

    def export(self, path: str) -> int:
        """Write the Chrome trace to ``path``; returns the event count."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, default=_json_scalar)
            f.write("\n")
        return len(doc["traceEvents"])


def _json_scalar(o):
    """numpy ints/floats riding in span args -> JSON scalars."""
    if isinstance(o, numbers.Integral):
        return int(o)
    if isinstance(o, numbers.Real):
        return float(o)
    raise TypeError(f"not JSON-serializable: {type(o)}")


# -- schema check ------------------------------------------------------------
_PH_REQUIRED = {
    "X": ("ts", "dur"),
    "B": ("ts",), "E": ("ts",),
    "i": ("ts",), "I": ("ts",),
    "C": ("ts",),
    "M": (),
}


def validate_chrome_trace(doc) -> int:
    """Minimal Trace Event JSON schema check (the CI gate): the object
    form with a ``traceEvents`` list whose events each carry a known
    ``ph``, a string ``name``, integer ``pid``/``tid``, and the
    non-negative numeric timing fields their phase requires.  Raises
    ``ValueError`` with the first offending event; returns the event
    count."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object: {e!r}")
        ph = e.get("ph")
        if ph not in _PH_REQUIRED:
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"event {i} has no name: {e!r}")
        for k in ("pid", "tid"):
            if not isinstance(e.get(k), numbers.Integral):
                raise ValueError(f"event {i} missing integer {k!r}: {e!r}")
        for k in _PH_REQUIRED[ph]:
            v = e.get(k)
            if not isinstance(v, numbers.Real) or v < 0:
                raise ValueError(
                    f"event {i} ({ph!r}) needs non-negative numeric "
                    f"{k!r}: {e!r}")
    return len(events)
