"""Engine serving counters as a dataclass, so the field list is the
single source of truth.

``EngineStats`` replaces the loose counter attributes the engine used to
grow one PR at a time: ``reset()`` walks ``dataclasses.fields`` and
restores every field to its declared default, so a newly added counter
can never silently survive a benchmark's warmup reset again — adding a
field IS adding its reset.  Derived rates live here too, all safe at
zero denominators (a fresh engine reports 0.0 rates and a ``None``
prefix hit rate, never a division crash or a misleading number).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class EngineStats:
    """Serving counters for one engine, zeroed by ``reset()`` between a
    benchmark's warmup and its measured phase."""

    steps: int = 0                   # unified device ticks issued
    generated_tokens: int = 0        # committed (recorded) tokens
    prefill_tokens: int = 0          # prompt/recompute tokens streamed
    peak_utilization: float = 0.0    # page-pool high-water mark
    bt_rows_synced: int = 0          # block-table rows re-uploaded
    ticks_nonempty: int = 0          # ticks that issued a device call
    ticks_cobatched: int = 0         # ...carrying >= 2 distinct submodels
    tokens_by_submodel: Dict[int, int] = field(default_factory=dict)
    peak_util_by_submodel: Dict[int, float] = field(default_factory=dict)
    # prefix-cache / COW accounting
    cache_hit_tokens: int = 0        # prompt tokens served from cache
    cache_eligible_tokens: int = 0   # prompt tokens lookups could cover
    prefill_tok_saved: int = 0       # hit tokens + ensemble fork savings
    cow_page_copies: int = 0         # device page copies issued
    # speculative-decode accounting
    spec_slot_ticks: int = 0         # (speculating slot, tick) pairs
    spec_drafted: int = 0            # draft tokens the parent verified
    spec_accepted: int = 0           # drafts that survived verification
    spec_committed: int = 0          # tokens committed by verify ticks

    def reset(self) -> None:
        """Restore every field to its declared default.  Derived from
        ``dataclasses.fields``, so a counter added tomorrow is reset
        tomorrow — there is no second list to forget to update."""
        for f in dataclasses.fields(self):
            if f.default_factory is not dataclasses.MISSING:
                setattr(self, f.name, f.default_factory())
            else:
                setattr(self, f.name, f.default)

    def as_dict(self) -> dict:
        """Shallow snapshot of every counter (dict fields copied)."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = dict(v) if isinstance(v, dict) else v
        return out

    # -- derived rates (all zero-denominator safe) ---------------------------
    @property
    def accept_rate(self) -> float:
        """Fraction of drafted tokens the parent accepted (0.0 when
        nothing was drafted)."""
        return self.spec_accepted / max(1, self.spec_drafted)

    @property
    def accepted_tok_per_tick(self) -> float:
        """Tokens committed per (speculating slot, tick) — 1.0 is plain
        decode's ceiling; 0.0 when nothing speculated."""
        return self.spec_committed / max(1, self.spec_slot_ticks)

    @property
    def cobatch_ratio(self) -> float:
        """Fraction of non-empty ticks whose single jitted call carried
        tokens from >= 2 distinct sub-models (0.0 before any tick)."""
        return self.ticks_cobatched / max(1, self.ticks_nonempty)

    @property
    def prefix_hit_rate(self) -> Optional[float]:
        """Fraction of cache-eligible prompt tokens served from the
        prefix cache — or None when nothing was eligible (cache
        disabled, or no lookup could match), so reports say "n/a"/null
        instead of a misleading 0.0."""
        if self.cache_eligible_tokens == 0:
            return None
        return self.cache_hit_tokens / self.cache_eligible_tokens
