"""SLO classes and per-class attainment scoring.

An ``SLOClass`` is a named pair of targets — TTFT and end-to-end
latency, in seconds — and the ``SLOTracker`` scores every finished
request against its class.  Attainment (fraction of requests meeting
*both* targets) is the signal the ROADMAP's elastic scheduler will
steer on: a class under attainment wants more slots or a bigger token
budget, a class over it can donate.

Classes parse from the ``name:ttft:latency`` CLI form
(``--slo-class interactive:0.5:5``); targets may be ``-`` or empty to
leave that bound unchecked.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

DEFAULT_CLASS = "default"


@dataclass(frozen=True)
class SLOClass:
    """Targets for one priority class; ``None`` means unbounded."""

    name: str
    ttft_s: Optional[float] = None
    latency_s: Optional[float] = None

    def meets(self, ttft_s: Optional[float],
              latency_s: Optional[float]) -> bool:
        """True when both bounds hold (an unset bound always holds; a
        missing measurement fails a set bound)."""
        if self.ttft_s is not None:
            if ttft_s is None or ttft_s > self.ttft_s:
                return False
        if self.latency_s is not None:
            if latency_s is None or latency_s > self.latency_s:
                return False
        return True


def parse_slo_class(spec: str) -> SLOClass:
    """``name:ttft:latency`` -> SLOClass; ``-``/empty leaves a bound
    unset.  ``interactive:0.5:5`` == TTFT <= 0.5 s and latency <= 5 s."""
    parts = spec.split(":")
    if not parts[0]:
        raise ValueError(f"SLO class needs a name: {spec!r}")
    if len(parts) > 3:
        raise ValueError(f"SLO class is name:ttft:latency, got {spec!r}")

    def bound(s: Optional[str]) -> Optional[float]:
        if s is None or s in ("", "-"):
            return None
        v = float(s)
        if not math.isfinite(v) or v <= 0:
            raise ValueError(f"SLO bound must be positive finite: {spec!r}")
        return v

    return SLOClass(parts[0],
                    bound(parts[1] if len(parts) > 1 else None),
                    bound(parts[2] if len(parts) > 2 else None))


@dataclass
class _ClassScore:
    finished: int = 0
    met: int = 0
    ttft_viol: int = 0
    lat_viol: int = 0


class SLOTracker:
    """Scores finished requests against their class targets.  Classes
    with no configured targets still accumulate (with trivially-met
    bounds), so the attainment report always covers every class seen."""

    def __init__(self, classes: Optional[List[SLOClass]] = None):
        self.classes: Dict[str, SLOClass] = {
            c.name: c for c in (classes or [])}
        self._scores: Dict[str, _ClassScore] = {}

    def add_class(self, cls: SLOClass) -> None:
        self.classes[cls.name] = cls

    def observe(self, slo_class: str, ttft_s: Optional[float],
                latency_s: Optional[float]) -> bool:
        """Score one finished request; returns whether it met its SLO."""
        cls = self.classes.get(slo_class) or SLOClass(slo_class)
        sc = self._scores.get(slo_class)
        if sc is None:
            sc = self._scores[slo_class] = _ClassScore()
        sc.finished += 1
        ok = cls.meets(ttft_s, latency_s)
        if ok:
            sc.met += 1
        else:
            if cls.ttft_s is not None and (
                    ttft_s is None or ttft_s > cls.ttft_s):
                sc.ttft_viol += 1
            if cls.latency_s is not None and (
                    latency_s is None or latency_s > cls.latency_s):
                sc.lat_viol += 1
        return ok

    def attainment(self, slo_class: str) -> Optional[float]:
        sc = self._scores.get(slo_class)
        if sc is None or sc.finished == 0:
            return None
        return sc.met / sc.finished

    def report(self) -> Dict[str, dict]:
        """Per-class attainment: the launcher's exit report and the
        elastic scheduler's steering input."""
        out = {}
        for name in sorted(self._scores):
            sc, cls = self._scores[name], self.classes.get(name)
            out[name] = {
                "finished": sc.finished,
                "met": sc.met,
                "attainment": sc.met / sc.finished if sc.finished else None,
                "ttft_target_s": cls.ttft_s if cls else None,
                "latency_target_s": cls.latency_s if cls else None,
                "ttft_violations": sc.ttft_viol,
                "latency_violations": sc.lat_viol,
            }
        return out

    def reset(self) -> None:
        self._scores.clear()
