"""Live anomaly detection over the serving metrics stream.

Four online detectors, each a small O(1)-per-observation state machine
fed by the telemetry hooks the engine already calls — no new device
work, no sample retention beyond a bounded rolling window:

  * ``TickSpikeDetector`` — robust z-score of each tick's duration
    against a rolling window: the baseline is the window's p10 (the
    contention-free cost of a tick, the same estimator the CI overhead
    gate uses) and the scale is the MAD.  A tick that is both many MADs
    above the median AND a multiple of the p10 fires — one slow tick
    under shared-box contention does not (the median/MAD absorb it),
    a forced recompile or a pathological host stall does.
  * ``BurnRateDetector`` — multi-window SLO burn rate (the SRE
    alerting pattern): each finished request is met/violated against
    its class targets; burn = violation fraction / error budget.  An
    alert needs the burn to exceed the threshold in BOTH a short and a
    long window, so a single outlier cannot fire (short window alone is
    noisy) and a slow leak cannot hide (long window alone lags).
  * ``PoolLeakWatchdog`` — every N ticks compares the pool's
    ``used_pages`` against the pages actually referenced by live
    request tables.  Copy-on-write and prefix forks SHARE pages, so the
    expectation counts distinct page ids — fork-heavy traffic stays
    silent; a page that no live table can reach (a lost ref-release)
    fires.
  * ``AcceptCollapseDetector`` — rolling speculative accept rate vs the
    run's long-run rate: a draft circuit that silently stops agreeing
    with its parent (weights swapped, masks corrupted, verify window
    bug) collapses committed tok/tick long before throughput counters
    make it obvious.

``AnomalyMonitor`` bundles them behind the hook surface Telemetry
drives and collects structured ``Alert`` records that are exported into
the Chrome trace (instant events), the metrics snapshot, and the serve
exit report."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

# -- alert kinds -------------------------------------------------------------
TICK_SPIKE = "tick_spike"
SLO_BURN = "slo_burn"
POOL_LEAK = "pool_leak"
ACCEPT_COLLAPSE = "accept_collapse"
RECOMPILE = "recompile"

ALERT_KINDS = (TICK_SPIKE, SLO_BURN, POOL_LEAK, ACCEPT_COLLAPSE, RECOMPILE)


@dataclass
class Alert:
    """One structured anomaly event (engine tick + clock it fired on)."""

    kind: str
    tick: int
    t: float                               # engine-clock seconds
    severity: str = "warning"              # "warning" | "critical"
    message: str = ""
    data: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"kind": self.kind, "tick": self.tick, "t": self.t,
                "severity": self.severity, "message": self.message,
                "data": dict(self.data)}


def _quantile(sorted_xs: List[float], q: float) -> float:
    """Nearest-rank quantile of an already-sorted sample."""
    i = min(len(sorted_xs) - 1, max(0, int(q * (len(sorted_xs) - 1))))
    return sorted_xs[i]


class TickSpikeDetector:
    """Robust z-score of tick duration vs a rolling window.

    Fires when a tick is ``z_thresh`` MADs above the rolling median AND
    at least ``min_ratio`` times the rolling p10 (the pooled-p10
    baseline).  The MAD floor (``scale_floor_frac`` of the median)
    keeps a near-constant-duration stream (MAD ~ 0) from firing on
    microsecond jitter.  ``cooldown`` ticks must pass between alerts so
    a sustained stall reports once per episode, not once per tick."""

    def __init__(self, window: int = 256, min_samples: int = 24,
                 z_thresh: float = 8.0, min_ratio: float = 3.0,
                 scale_floor_frac: float = 0.05, cooldown: int = 16):
        self.win: Deque[float] = deque(maxlen=window)
        self.min_samples = min_samples
        self.z_thresh = z_thresh
        self.min_ratio = min_ratio
        self.scale_floor_frac = scale_floor_frac
        self.cooldown = cooldown
        self._last_fire = -10**9

    def observe(self, tick: int, dur_s: float) -> Optional[dict]:
        """Feed one tick duration; returns alert data when it spikes.
        The spiking tick is NOT added to the window (a genuine anomaly
        must not drag the baseline up toward itself)."""
        fired = None
        if len(self.win) >= self.min_samples \
                and tick - self._last_fire >= self.cooldown:
            xs = sorted(self.win)
            med = _quantile(xs, 0.5)
            p10 = _quantile(xs, 0.10)
            mad = _quantile(sorted(abs(x - med) for x in xs), 0.5)
            scale = max(1.4826 * mad, self.scale_floor_frac * med, 1e-9)
            z = (dur_s - med) / scale
            if z > self.z_thresh and dur_s > self.min_ratio * max(p10, 1e-9):
                self._last_fire = tick
                fired = {"dur_s": dur_s, "z": round(z, 2),
                         "median_s": med, "p10_s": p10}
        if fired is None:
            self.win.append(dur_s)
        return fired


class BurnRateDetector:
    """Multi-window SLO burn-rate alerting for one class.

    ``budget`` is the allowed violation fraction (SLO 99% => 0.01);
    burn rate = observed violation fraction / budget.  Fires when burn
    exceeds ``burn_thresh`` over BOTH the short and the long window
    (each at least ``min_samples`` full), then resets the windows so
    one sustained violation episode reports once."""

    def __init__(self, budget: float = 0.1, burn_thresh: float = 2.0,
                 short_window: int = 16, long_window: int = 64,
                 min_samples: int = 8):
        if not 0 < budget < 1:
            raise ValueError(f"budget must be in (0, 1): {budget}")
        self.budget = budget
        self.burn_thresh = burn_thresh
        self.short: Deque[bool] = deque(maxlen=short_window)
        self.long: Deque[bool] = deque(maxlen=long_window)
        self.min_samples = min_samples

    def _burn(self, win: Deque[bool]) -> float:
        if not win:
            return 0.0
        return (sum(win) / len(win)) / self.budget

    def observe(self, violated: bool) -> Optional[dict]:
        self.short.append(bool(violated))
        self.long.append(bool(violated))
        if len(self.short) < max(self.min_samples, 1) \
                or len(self.long) < max(self.min_samples, 1):
            return None
        bs, bl = self._burn(self.short), self._burn(self.long)
        if bs >= self.burn_thresh and bl >= self.burn_thresh:
            data = {"short_burn": round(bs, 3), "long_burn": round(bl, 3),
                    "budget": self.budget,
                    "short_n": len(self.short), "long_n": len(self.long)}
            self.short.clear()
            self.long.clear()
            return data
        return None


class PoolLeakWatchdog:
    """Every ``every`` ticks: ``used_pages`` (pool pages neither free
    nor cached) must be explainable by the pages live request tables
    reference — COW/fork shares are counted once via distinct page ids,
    so legitimate sharing never fires.  ``slack_pages`` absorbs
    transient bookkeeping (e.g. deferred-reserve promises mid-tick)."""

    def __init__(self, every: int = 32, slack_pages: int = 0):
        self.every = max(1, every)
        self.slack_pages = slack_pages
        self._last_check = -1

    def due(self, tick: int) -> bool:
        return tick - self._last_check >= self.every

    def check(self, tick: int, used_pages: int,
              live_pages: int) -> Optional[dict]:
        """``live_pages`` = distinct pages referenced by live sequences
        (running + waiting-preempted still holding refs)."""
        self._last_check = tick
        leaked = used_pages - live_pages - self.slack_pages
        if leaked > 0:
            return {"used_pages": used_pages, "live_pages": live_pages,
                    "leaked_pages": leaked}
        return None


class AcceptCollapseDetector:
    """Rolling speculative accept rate vs the run's long-run rate.

    After ``min_drafted`` tokens establish a long-run baseline, an
    alert fires when the rolling-window accept rate drops below
    ``collapse_frac`` of that baseline (and below ``abs_floor``
    absolutely — a run whose baseline is already terrible should not
    alert on noise around terrible)."""

    def __init__(self, window: int = 64, min_drafted: int = 64,
                 collapse_frac: float = 0.5, abs_floor: float = 0.5):
        self.win: Deque[tuple] = deque(maxlen=window)   # (drafted, accepted)
        self.min_drafted = min_drafted
        self.collapse_frac = collapse_frac
        self.abs_floor = abs_floor
        self.total_drafted = 0
        self.total_accepted = 0
        self._fired = False

    def observe(self, drafted: int, accepted: int) -> Optional[dict]:
        if drafted <= 0:
            return None
        self.total_drafted += drafted
        self.total_accepted += accepted
        self.win.append((drafted, accepted))
        if self.total_drafted < self.min_drafted:
            return None
        wd = sum(d for d, _ in self.win)
        wa = sum(a for _, a in self.win)
        if wd < self.min_drafted // 2:
            return None
        rolling = wa / wd
        longrun = self.total_accepted / self.total_drafted
        collapsed = rolling < self.collapse_frac * longrun \
            and rolling < self.abs_floor
        if collapsed and not self._fired:
            self._fired = True          # once per collapse episode
            return {"rolling_accept": round(rolling, 4),
                    "longrun_accept": round(longrun, 4),
                    "window_drafted": wd}
        if not collapsed and rolling >= self.collapse_frac * longrun:
            self._fired = False         # recovered: re-arm
        return None


class AnomalyMonitor:
    """The detectors behind one hook surface (driven by ``Telemetry``).

    ``alerts`` accumulates structured records; ``on_alert`` (set by the
    Telemetry that owns the monitor) additionally routes each alert
    into the tick timeline and the metrics registry the moment it
    fires."""

    def __init__(self, *, spike: Optional[TickSpikeDetector] = None,
                 burn: Optional[Dict[str, float]] = None,
                 leak: Optional[PoolLeakWatchdog] = None,
                 accept: Optional[AcceptCollapseDetector] = None,
                 max_alerts: int = 1024):
        self.spike = spike if spike is not None else TickSpikeDetector()
        self._burn_kw = dict(burn or {})
        self._burn: Dict[str, BurnRateDetector] = {}   # per SLO class
        self.leak = leak if leak is not None else PoolLeakWatchdog()
        self.accept = accept if accept is not None \
            else AcceptCollapseDetector()
        self.alerts: Deque[Alert] = deque(maxlen=max_alerts)
        self.counts: Dict[str, int] = {}
        self.on_alert: Optional[Callable[[Alert], None]] = None
        self._tick = 0
        self._t = 0.0

    # -- emission ------------------------------------------------------------
    def _emit(self, kind: str, data: dict, severity: str = "warning",
              message: str = "") -> None:
        a = Alert(kind, self._tick, self._t, severity, message, data)
        self.alerts.append(a)
        self.counts[kind] = self.counts.get(kind, 0) + 1
        if self.on_alert is not None:
            self.on_alert(a)

    # -- hooks ---------------------------------------------------------------
    def on_tick(self, tick: int, t: float, dur_s: float, *,
                used_pages: Optional[int] = None,
                live_pages: Optional[Callable[[], int]] = None) -> None:
        """One engine tick: ``dur_s`` wall duration; ``live_pages`` is a
        zero-arg callable evaluated only when the leak watchdog is due
        (counting distinct pages walks every live table — cheap, but
        not every-tick cheap)."""
        self._tick, self._t = tick, t
        hit = self.spike.observe(tick, dur_s)
        if hit:
            self._emit(TICK_SPIKE, hit,
                       message=f"tick {tick} took {dur_s * 1e3:.1f}ms "
                               f"(z={hit['z']}, p10 "
                               f"{hit['p10_s'] * 1e3:.1f}ms)")
        if used_pages is not None and live_pages is not None \
                and self.leak.due(tick):
            hit = self.leak.check(tick, used_pages, live_pages())
            if hit:
                self._emit(POOL_LEAK, hit, severity="critical",
                           message=f"{hit['leaked_pages']} page(s) used "
                                   f"but unreachable from live tables")

    def on_finish(self, slo_class: str, met: bool, t: float) -> None:
        self._t = t
        det = self._burn.get(slo_class)
        if det is None:
            det = self._burn[slo_class] = BurnRateDetector(**self._burn_kw)
        hit = det.observe(not met)
        if hit:
            self._emit(SLO_BURN, {"slo_class": slo_class, **hit},
                       message=f"class {slo_class!r} burning "
                               f"{hit['short_burn']}x budget over both "
                               f"windows")

    def on_speculate(self, drafted: int, accepted: int, t: float) -> None:
        self._t = t
        hit = self.accept.observe(drafted, accepted)
        if hit:
            self._emit(ACCEPT_COLLAPSE, hit,
                       message=f"accept rate collapsed to "
                               f"{hit['rolling_accept']:.0%} (long-run "
                               f"{hit['longrun_accept']:.0%})")

    def on_compile(self, name: str, variant: str, dur_s: float,
                   post_warm: bool) -> None:
        """A jit compile observed by the step profiler.  Compiles during
        warmup are expected; a compile AFTER the warmup boundary
        (``Engine.reset_stats``) is the classic silent perf regression
        and alerts."""
        if post_warm:
            self._emit(RECOMPILE,
                       {"step": name, "variant": variant,
                        "compile_s": round(dur_s, 4)},
                       message=f"post-warmup recompile of {variant} "
                               f"({dur_s * 1e3:.0f}ms)")

    # -- read side -----------------------------------------------------------
    def report(self) -> dict:
        """Counts + the retained alert records (JSON-ready)."""
        return {"counts": dict(self.counts),
                "alerts": [a.as_dict() for a in self.alerts]}

    def reset(self) -> None:
        """Warmup boundary: drop alerts and detector state (compile
        warm-marking lives in the profiler, not here)."""
        self.alerts.clear()
        self.counts.clear()
        self.spike = TickSpikeDetector(
            window=self.spike.win.maxlen,
            min_samples=self.spike.min_samples,
            z_thresh=self.spike.z_thresh, min_ratio=self.spike.min_ratio,
            scale_floor_frac=self.spike.scale_floor_frac,
            cooldown=self.spike.cooldown)
        self._burn.clear()
        self.accept = AcceptCollapseDetector(
            window=self.accept.win.maxlen,
            min_drafted=self.accept.min_drafted,
            collapse_frac=self.accept.collapse_frac,
            abs_floor=self.accept.abs_floor)
        self.leak = PoolLeakWatchdog(every=self.leak.every,
                                     slack_pages=self.leak.slack_pages)
        self._tick, self._t = 0, 0.0
