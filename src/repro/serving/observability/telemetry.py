"""The engine's one telemetry object: registry + lifecycle tracer +
tick timeline + SLO tracker behind a single set of hooks.

The engine calls ``on_*`` at each lifecycle transition and ``on_tick``
once per device call; everything else (the periodic stats line, the
benchmark snapshot, the SLO exit report, the Perfetto export) *reads*
from here.  All hooks are host-side appends and dict updates — nothing
crosses the jit boundary — and each recorder can be switched off
independently (``Telemetry(tracer=False, timeline=False)`` is the
observability-off baseline the CI overhead gate compares against).

Lifecycle metrics use the engine clock (the ``arrival_time`` / ``now``
values the scheduler stamps onto requests) so trace-derived TTFT and
latency match the request timestamps exactly; the tick timeline uses
``time.perf_counter`` for microsecond phase spans.
"""
from __future__ import annotations

from typing import List, Optional

from . import trace as TR
from .anomaly import AnomalyMonitor
from .metrics import MetricsRegistry
from .profiler import StepProfiler
from .slo import DEFAULT_CLASS, SLOClass, SLOTracker
from .trace import RequestTracer, TickTimeline

# finished traces kept by default; a long-running server drops the
# oldest instead of growing without bound (launcher/tests that want
# everything pass trace_maxlen=None explicitly... via Telemetry(...))
TRACE_KEEP_DEFAULT = 4096


class Telemetry:
    def __init__(self, *, tracer: bool = True, timeline: bool = False,
                 slo_classes: Optional[List[SLOClass]] = None,
                 trace_maxlen: Optional[int] = TRACE_KEEP_DEFAULT,
                 profiler: bool = True, anomaly: bool = True):
        self.registry = MetricsRegistry()
        self.tracer: Optional[RequestTracer] = \
            RequestTracer(maxlen=trace_maxlen) if tracer else None
        self.timeline: Optional[TickTimeline] = \
            TickTimeline() if timeline else None
        self.slo = SLOTracker(slo_classes)
        self.profiler: Optional[StepProfiler] = \
            StepProfiler() if profiler else None
        self.anomaly: Optional[AnomalyMonitor] = \
            AnomalyMonitor() if anomaly else None
        if self.profiler is not None:
            self.profiler.on_compile = self._on_compile_event
        if self.anomaly is not None:
            self.anomaly.on_alert = self._on_alert
        self.engine_config: dict = {}
        # streaming latency distributions, labeled by SLO class; exact
        # sample percentiles (benchmarks) still come from request
        # timestamps via metrics.percentile — same ground truth, the
        # histograms are the no-sample-retention view
        self.ttft_s = self.registry.histogram("ttft_s")
        self.latency_s = self.registry.histogram("latency_s")
        self.queue_s = self.registry.histogram("queue_s")
        self.preempt_wait_s = self.registry.histogram("preempt_wait_s")
        self.tick_s = self.registry.histogram("tick_s")
        self.tokens_per_tick = self.registry.histogram(
            "tokens_per_tick", lo=0.5, hi=65536.0, growth=1.15)

    # -- wiring (engine construction time) -----------------------------------
    def set_engine_config(self, **cfg) -> None:
        """Stamp the engine's tuning knobs (kv_dtype, pages_per_step,
        speculate_k, bank size, ...) into the trace metadata block and
        the metrics snapshot — two exported traces from differently
        configured engines must be tellable apart without filenames."""
        self.engine_config.update(cfg)
        if self.timeline is not None:
            self.timeline.set_metadata(**cfg)

    def _on_compile_event(self, ev) -> None:
        """Profiler observed a jit compile: first-class timeline span,
        registry counter, and (post-warmup) a recompile alert."""
        if self.timeline is not None:
            self.timeline.span("jit_compile", ev.t0, ev.t0 + ev.dur_s,
                               variant=ev.variant, post_warm=ev.post_warm)
        self.registry.counter("compiles").inc(
            label="post_warm" if ev.post_warm else "warmup")
        if self.anomaly is not None:
            self.anomaly.on_compile(ev.name, ev.variant, ev.dur_s,
                                    ev.post_warm)

    def _on_alert(self, alert) -> None:
        """Anomaly fired: structured instant in the trace export plus a
        per-kind counter — the alert is visible in Perfetto at the tick
        it fired, in ``Engine.metrics()``, and in the exit report."""
        if self.timeline is not None:
            self.timeline.instant(
                f"alert:{alert.kind}", tick=alert.tick,
                severity=alert.severity, message=alert.message,
                **{k: v for k, v in alert.data.items()
                   if isinstance(v, (int, float, str, bool))})
        self.registry.counter("alerts").inc(label=alert.kind)

    # -- request lifecycle hooks (engine clock) ------------------------------
    def on_submit(self, req, t: float) -> None:
        if self.tracer is not None:
            self.tracer.record(req.id, TR.SUBMIT, t,
                               prompt_len=req.prompt_len,
                               submodel=req.submodel_id,
                               slo_class=req.slo_class)

    def on_admit(self, req, t: float) -> None:
        if self.tracer is not None:
            self.tracer.record(req.id, TR.ADMIT, t, slot=req.slot,
                               cached=req.num_cached_tokens)
            if req.num_cached_tokens:
                self.tracer.record(req.id, TR.PREFIX_ADOPT, t,
                                   n=req.num_cached_tokens)
        if self.timeline is not None:
            self.timeline.instant("admit", req=req.id, slot=req.slot)

    def on_prefill_chunk(self, req, t: float, start: int, n: int) -> None:
        if self.tracer is not None:
            self.tracer.record(req.id, TR.PREFILL_CHUNK, t, start=start, n=n)

    def on_token(self, req, t: float, n: int = 1) -> None:
        if self.tracer is not None:
            self.tracer.record(req.id, TR.TOKEN, t, n=n)

    def on_speculate(self, req, t: float, drafted: int, accepted: int,
                     committed: int) -> None:
        if self.tracer is not None:
            self.tracer.record(req.id, TR.SPECULATE, t, drafted=drafted,
                               accepted=accepted, n=committed)
        if self.anomaly is not None:
            self.anomaly.on_speculate(drafted, accepted, t)

    def on_preempt(self, req, t: float) -> None:
        if self.tracer is not None:
            self.tracer.record(req.id, TR.PREEMPT, t,
                               context_len=req.context_len)
        if self.timeline is not None:
            self.timeline.instant("preempt", req=req.id)

    def on_finish(self, req, t: float) -> None:
        """Score + histogram the finished request.  Ensemble members
        share one delivered stream, so only the leader lands in the
        latency distributions and the SLO ledger (matching
        ``finished_streams``); the trace still closes for every member."""
        if self.tracer is not None:
            self.tracer.record(req.id, TR.FINISH, t,
                               tokens=len(req.out_tokens),
                               preemptions=req.num_preemptions)
        if req.group is not None and req is not req.group.leader:
            return
        cls = req.slo_class or DEFAULT_CLASS
        ttft = None if req.t_first_token is None \
            else req.t_first_token - req.arrival_time
        lat = t - req.arrival_time
        if ttft is not None:
            self.ttft_s.observe(ttft, label=cls)
        self.latency_s.observe(lat, label=cls)
        if req.t_admitted is not None:
            self.queue_s.observe(req.t_admitted - req.arrival_time,
                                 label=cls)
        if self.tracer is not None:
            tr = self.tracer.get(req.id)
            if tr is not None and tr.num_preemptions:
                self.preempt_wait_s.observe(tr.preempt_wait_s, label=cls)
        ok = self.slo.observe(cls, ttft, lat)
        if self.anomaly is not None:
            self.anomaly.on_finish(cls, ok, t)

    # -- per-tick hook (perf_counter clock) ----------------------------------
    def on_tick(self, tick: int, marks, slot_events=(), extra_spans=(),
                counters: Optional[dict] = None, tokens: int = 0,
                t: float = 0.0, used_pages: Optional[int] = None,
                live_pages=None, kv_read_bytes: int = 0) -> None:
        """``t`` is the engine-clock tick time (alerts are stamped with
        it); ``used_pages``/``live_pages`` feed the pool-leak watchdog
        (``live_pages`` a zero-arg callable, evaluated only when due);
        ``kv_read_bytes`` is the tick's estimated KV traffic for the
        roofline gauges."""
        dur = marks[-1] - marks[0]
        self.tick_s.observe(dur)
        if tokens:
            self.tokens_per_tick.observe(tokens)
        if self.timeline is not None:
            self.timeline.add_tick(tick, marks, slot_events=slot_events,
                                   extra_spans=extra_spans,
                                   counters=counters)
        if self.anomaly is not None:
            self.anomaly.on_tick(tick, t, dur, used_pages=used_pages,
                                 live_pages=live_pages)
        # per-tick roofline gauges: what the device achieved this tick
        # vs. the kernel_bench reference rates (when set via the
        # profiler); device_step phase time is marks[3] - marks[2]
        if tokens and self.profiler is not None:
            dev = max(marks[3] - marks[2], 1e-9)
            r = self.registry
            r.gauge("achieved_tok_s").set(tokens / dev)
            if kv_read_bytes:
                r.gauge("achieved_kv_gb_s").set(kv_read_bytes / dev / 1e9)
            peaks = self.profiler.peaks
            if peaks.get("tok_s"):
                r.gauge("roofline_tok_frac").set(
                    tokens / dev / peaks["tok_s"])
            if peaks.get("kv_gb_s") and kv_read_bytes:
                r.gauge("roofline_kv_frac").set(
                    kv_read_bytes / dev / 1e9 / peaks["kv_gb_s"])

    # -- read side -----------------------------------------------------------
    def collect(self, engine) -> MetricsRegistry:
        """Publish the engine's current counters and pool/router/spec
        state into registry gauges (per-label views included), so one
        ``registry.snapshot()`` is the complete picture."""
        r, stats = self.registry, engine.stats
        for name, v in stats.as_dict().items():
            if isinstance(v, dict):
                g = r.gauge(name)
                for label, x in v.items():
                    g.set(x, label=label)
                g.set(sum(v.values()) if name == "tokens_by_submodel"
                      else max(v.values(), default=0.0))
            else:
                r.gauge(name).set(v)
        for name in ("accept_rate", "accepted_tok_per_tick",
                     "cobatch_ratio"):
            r.gauge(name).set(getattr(stats, name))
        hr = stats.prefix_hit_rate
        if hr is not None:
            r.gauge("prefix_hit_rate").set(hr)
        pool = r.gauge("pool_utilization")
        pool.set(engine.pool.utilization())
        for owner, util in engine.pool.utilization_by_owner().items():
            pool.set(util, label=owner)
        for name, v in engine.pool.stats().items():
            if not isinstance(v, dict):
                r.gauge(f"pool_{name}").set(v)
        if engine.pool.cache is not None:
            for name, v in engine.pool.cache.stats().items():
                r.gauge(f"prefix_cache_{name}").set(v)
        if engine.router is not None:
            g = r.gauge("router_load")
            for sid, load in enumerate(engine.router.loads):
                g.set(load, label=sid)
            routed = r.gauge("router_routed")
            for sid, n in enumerate(engine.router.routed):
                routed.set(n, label=sid)
        if engine.spec is not None:
            for name, v in engine.spec.stats().items():
                r.gauge(f"spec_{name}").set(v)
        r.gauge("preemptions").set(engine.preemptions)
        r.gauge("cache_evictions").set(engine.cache_evictions)
        if self.profiler is not None:
            r.gauge("compiles_total").set(self.profiler.compiles_total)
            r.gauge("compiles_post_warm").set(
                self.profiler.compiles_post_warm)
        if self.anomaly is not None:
            g = r.gauge("anomaly_alerts")
            g.set(sum(self.anomaly.counts.values()))
            for kind, n in self.anomaly.counts.items():
                g.set(n, label=kind)
        return r

    def snapshot(self, engine) -> dict:
        """The nested read surface: counters + derived rates + subsystem
        stats + latency/tick summaries + SLO attainment.  The launcher's
        stats line and the benchmark phases consume this instead of
        reaching into engine internals."""
        stats = engine.stats
        out = {
            "counters": stats.as_dict(),
            "derived": {
                "accept_rate": stats.accept_rate,
                "accepted_tok_per_tick": stats.accepted_tok_per_tick,
                "cobatch_ratio": stats.cobatch_ratio,
                "prefix_hit_rate": stats.prefix_hit_rate,
                "cache_evictions": engine.cache_evictions,
                "preemptions": engine.preemptions,
            },
            "pool": engine.pool.stats(),
            "latency": {
                "ttft_s": self.ttft_s.summary(),
                "latency_s": self.latency_s.summary(),
                "queue_s": self.queue_s.summary(),
                "preempt_wait_s": self.preempt_wait_s.summary(),
            },
            "tick": {
                "tick_s": self.tick_s.summary(),
                "tokens_per_tick": self.tokens_per_tick.summary(),
            },
            "slo": self.slo.report(),
        }
        if engine.pool.cache is not None:
            out["prefix_cache"] = engine.pool.cache.stats()
        if engine.router is not None:
            out["router"] = engine.router.stats()
        if engine.spec is not None:
            out["spec"] = engine.spec.stats()
        if self.tracer is not None:
            out["trace_events"] = self.tracer.num_events
        if self.timeline is not None:
            out["timeline_events"] = self.timeline.num_events
        if self.engine_config:
            out["config"] = dict(self.engine_config)
        if self.profiler is not None:
            # compute=False: never pay an AOT compile on the stats-line
            # path — costs appear once something (exit report, regression
            # harness) has called profiler.cost_report()
            out["profiler"] = {
                "compiles_total": self.profiler.compiles_total,
                "compiles_post_warm": self.profiler.compiles_post_warm,
                "cost": self.profiler.cost_report(compute=False),
            }
        if self.anomaly is not None:
            out["alerts"] = self.anomaly.report()
        return out

    def reset(self) -> None:
        """Benchmark warmup boundary: drop every recorded sample/event so
        the measured phase starts clean (the engine's own counter reset
        lives in ``EngineStats.reset``)."""
        self.registry.reset()
        if self.tracer is not None:
            self.tracer.clear()
        if self.timeline is not None:
            self.timeline.clear()
        self.slo.reset()
        if self.anomaly is not None:
            self.anomaly.reset()
        if self.profiler is not None:
            # a reset IS the warmup boundary: compiles before it were
            # expected, compiles after it alert as regressions
            self.profiler.reset()
            self.profiler.mark_warm()
