"""Traffic-trace record/replay: versioned JSONL request streams and a
deterministic virtual-clock drive loop.

A trace is the *workload*, separated from the wall clock that happened
to deliver it: each record pins a request's arrival offset, exact
prompt token ids, generation budget, SLO class, and ensemble flag.
Replaying drives the engine on a **virtual clock** — arrivals are
submitted when virtual time passes their offset and every tick advances
time by a fixed ``tick_dt`` — so admission order, preemption points,
chunking, TTFT, and latency are functions of the trace alone, not of
host load.  With greedy sampling the committed token streams are
byte-identical run-to-run (the regression harness pins the SHA-256 of
the streams), and trace-derived TTFT/latency are exactly reproducible;
only the per-tick *wall* durations differ between runs — which is
precisely the quantity the perf gate estimates robustly (pooled p10)
rather than trusting.

File format (JSONL, one object per line):

  line 1   header: ``{"schema": "horn-serving-trace", "version": 1,
           "meta": {...engine/workload provenance...}}``
  line 2+  one record per request, sorted by ``arrival_s``:
           ``{"arrival_s": float, "prompt": [int, ...],
           "max_new_tokens": int, "slo_class": str,
           "ensemble": str | null, "submodel_id": int | null,
           "session": str | null}``

``serve.py --record-trace`` writes one; ``serve.py --replay`` and
``benchmarks/regression.py`` consume them (pinned copies live under
``benchmarks/traces/``)."""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

SCHEMA = "horn-serving-trace"
VERSION = 1

# Virtual seconds one engine tick advances during replay.  The value is
# part of replay semantics (it scales trace-derived TTFT/latency and
# decides how many arrivals land between ticks), so the regression
# baselines pin it; 10ms approximates a healthy CPU tick and keeps
# Poisson traces recorded at rate ~16 req/s interleaving realistically.
DEFAULT_TICK_DT = 0.01


@dataclass
class TraceRecord:
    """One request of a recorded stream."""

    arrival_s: float
    prompt: List[int]
    max_new_tokens: int
    slo_class: str = "default"
    ensemble: Optional[str] = None         # combine mode or None (solo)
    submodel_id: Optional[int] = None      # routing hint (None = router)
    session: Optional[str] = None          # affinity key for hash routing

    def as_dict(self) -> dict:
        d = {"arrival_s": round(float(self.arrival_s), 6),
             "prompt": [int(t) for t in self.prompt],
             "max_new_tokens": int(self.max_new_tokens),
             "slo_class": self.slo_class}
        if self.ensemble is not None:
            d["ensemble"] = self.ensemble
        if self.submodel_id is not None:
            d["submodel_id"] = int(self.submodel_id)
        if self.session is not None:
            d["session"] = self.session
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TraceRecord":
        return cls(arrival_s=float(d["arrival_s"]),
                   prompt=[int(t) for t in d["prompt"]],
                   max_new_tokens=int(d["max_new_tokens"]),
                   slo_class=d.get("slo_class", "default"),
                   ensemble=d.get("ensemble"),
                   submodel_id=d.get("submodel_id"),
                   session=d.get("session"))


def save_trace(path: str, records: List[TraceRecord],
               meta: Optional[dict] = None) -> int:
    """Write header + records (sorted by arrival, stable) as JSONL."""
    recs = sorted(records, key=lambda r: r.arrival_s)
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA, "version": VERSION,
                   "meta": dict(meta or {})}, f, sort_keys=True)
        f.write("\n")
        for r in recs:
            json.dump(r.as_dict(), f, sort_keys=True)
            f.write("\n")
    return len(recs)


def load_trace(path: str) -> Tuple[List[TraceRecord], dict]:
    """Parse a JSONL trace; returns (records, header-meta).  Rejects
    unknown schemas/major versions up front — a silently misread trace
    would produce a confidently wrong regression verdict."""
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty trace file")
    head = json.loads(lines[0])
    if head.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: schema {head.get('schema')!r} != {SCHEMA!r}")
    if int(head.get("version", -1)) > VERSION:
        raise ValueError(
            f"{path}: trace version {head.get('version')} is newer than "
            f"supported version {VERSION}")
    records = [TraceRecord.from_dict(json.loads(ln)) for ln in lines[1:]]
    if not records:
        raise ValueError(f"{path}: trace has a header but no records")
    return records, head.get("meta", {})


class TraceRecorder:
    """Accumulates records during a live run (``serve.py
    --record-trace``): call ``add`` with exactly what was submitted —
    including the *resolved* ensemble decision, so replay does not
    depend on the recorder's RNG state — then ``save``."""

    def __init__(self, meta: Optional[dict] = None):
        self.records: List[TraceRecord] = []
        self.meta = dict(meta or {})

    def add(self, arrival_s: float, prompt, max_new_tokens: int, *,
            slo_class: str = "default", ensemble: Optional[str] = None,
            submodel_id: Optional[int] = None,
            session: Optional[str] = None) -> None:
        self.records.append(TraceRecord(
            arrival_s=float(arrival_s), prompt=list(map(int, prompt)),
            max_new_tokens=int(max_new_tokens), slo_class=slo_class,
            ensemble=ensemble, submodel_id=submodel_id, session=session))

    def save(self, path: str) -> int:
        return save_trace(path, self.records, self.meta)


def stream_digest(streams: List[Tuple[int, List[int]]]) -> str:
    """SHA-256 over the canonical JSON of ``[[index, [token, ...]],
    ...]`` — indices are per-replay submission order (NOT engine request
    ids, which keep incrementing across replays on a reused engine), so
    two replays of the same trace on the same engine can be compared."""
    doc = [[int(i), [int(t) for t in toks]] for i, toks in streams]
    return hashlib.sha256(
        json.dumps(doc, separators=(",", ":")).encode()).hexdigest()


@dataclass
class ReplayResult:
    """Everything a determinism check or a regression gate reads.

    ``streams``/``ttft_s``/``latency_s`` are trace-derived and
    deterministic; ``tick_wall_s`` is the only wall-clock quantity (the
    per-tick host+device durations the pooled-p10 throughput estimator
    consumes)."""

    requests: int
    ticks: int
    generated_tokens: int
    streams: List[Tuple[int, List[int]]]   # (submission index, tokens)
    token_digest: str
    ttft_s: List[float]                    # virtual-clock, per stream
    latency_s: List[float]
    tick_wall_s: List[float]               # wall, per non-trivial tick
    tick_dt: float
    accept_rate: float = 0.0
    virtual_s: float = 0.0                 # virtual makespan
    alerts: List[dict] = field(default_factory=list)

    def summary(self) -> dict:
        """JSON-ready gate inputs.  Decode throughput uses the pooled
        p10 of per-tick wall durations — the contention-robust estimate
        of what a tick costs on an otherwise-idle machine — times the
        tick count, never the run's wall clock."""
        from .metrics import percentile_or_none
        walls = sorted(self.tick_wall_s)
        p10 = walls[max(0, int(0.10 * (len(walls) - 1)))] if walls else None
        tok_s = None
        if p10 and self.ticks:
            tok_s = round(self.generated_tokens / (p10 * self.ticks), 2)
        return {
            "requests": self.requests,
            "ticks": self.ticks,
            "generated_tokens": self.generated_tokens,
            "token_digest": self.token_digest,
            "ttft_p50_s": percentile_or_none(self.ttft_s, 50),
            "ttft_p99_s": percentile_or_none(self.ttft_s, 99),
            "latency_p50_s": percentile_or_none(self.latency_s, 50),
            "latency_p99_s": percentile_or_none(self.latency_s, 99),
            "tick_p10_wall_s": None if p10 is None else round(p10, 6),
            "decode_tok_s_p10": tok_s,
            "accept_rate": round(self.accept_rate, 4),
            "virtual_s": round(self.virtual_s, 4),
            "alerts": len(self.alerts),
        }


def replay(engine, records: List[TraceRecord], *,
           tick_dt: float = DEFAULT_TICK_DT, reset: bool = True,
           max_ticks: int = 1_000_000,
           clock=time.perf_counter) -> ReplayResult:
    """Drive ``engine`` through ``records`` on the virtual clock.

    ``reset=True`` zeroes stats/telemetry first (the warmup-boundary
    reset — compile caches and the prefix cache deliberately survive,
    exactly like the benchmarks' measured phase).  The engine must have
    been built compatibly with the trace's meta (the callers check);
    temperature 0 (greedy) is what makes streams byte-identical."""
    recs = sorted(records, key=lambda r: r.arrival_s)
    if reset:
        engine.reset_stats()
    submitted: List[Tuple[int, object]] = []   # (index, Request | group)
    ticks = 0
    tick_wall_s: List[float] = []
    now, i = 0.0, 0
    while i < len(recs) or engine.sched.has_work():
        while i < len(recs) and recs[i].arrival_s <= now:
            r = recs[i]
            out = engine.submit(
                r.prompt, r.max_new_tokens, arrival_time=r.arrival_s,
                ensemble=r.ensemble, submodel_id=r.submodel_id,
                session=r.session, slo_class=r.slo_class)
            submitted.append((i, out))
            i += 1
        if not engine.sched.has_work():
            now = max(now, recs[i].arrival_s)     # idle-skip to next arrival
            continue
        w0 = clock()
        engine.step(now, tick_clock=lambda: now + tick_dt)
        tick_wall_s.append(clock() - w0)
        ticks += 1
        now += tick_dt
        if ticks > max_ticks:
            raise RuntimeError(
                f"replay exceeded {max_ticks} ticks with "
                f"{len(engine.sched.waiting)} waiting / "
                f"{len(engine.sched.running)} running — wedged engine?")

    streams: List[Tuple[int, List[int]]] = []
    ttft: List[float] = []
    lat: List[float] = []
    for idx, out in submitted:
        # an ensemble group delivers ONE stream (its leader's)
        req = out.leader if hasattr(out, "leader") else out
        streams.append((idx, [int(t) for t in req.out_tokens]))
        if req.t_first_token is not None:
            ttft.append(req.t_first_token - req.arrival_time)
        if req.t_done is not None:
            lat.append(req.t_done - req.arrival_time)

    alerts = []
    mon = getattr(engine.obs, "anomaly", None)
    if mon is not None:
        alerts = [a.as_dict() for a in mon.alerts]
    return ReplayResult(
        requests=len(recs), ticks=ticks,
        generated_tokens=engine.stats.generated_tokens,
        streams=streams, token_digest=stream_digest(streams),
        ttft_s=ttft, latency_s=lat, tick_wall_s=tick_wall_s,
        tick_dt=tick_dt, accept_rate=engine.stats.accept_rate,
        virtual_s=now, alerts=alerts)
