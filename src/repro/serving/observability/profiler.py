"""Device-step cost attribution: compile-event capture and per-variant
``cost_analysis()`` for the engine's jitted step functions.

The engine's whole device story is a handful of jitted callables (the
unified step's two ensemble variants, each specialised per chunk-width
bucket and verify-window extent).  Two things about them are prime
silent regressions:

  * **Recompiles.**  A chunk width the warmup sweep never minted, a
    static flag flipping mid-run, or an upstream cache flush turns one
    cheap tick into a multi-second trace+compile stall.  The profiler
    watches each jitted callable's compile-cache size across calls — a
    growth is a compile, stamped with the call's wall duration and
    whether it happened after the warmup boundary (``mark_warm``,
    driven by ``Engine.reset_stats``).  Post-warm compiles surface as
    first-class ``TickTimeline`` spans, ``Engine.metrics()`` counters,
    and a ``recompile`` anomaly alert.
  * **Cost drift.**  ``cost_analysis()`` FLOPs / HBM-bytes per compiled
    variant put a number on what each tick *asks* the device to do, so
    a PR that doubles the bytes-accessed of the decode step is visible
    in the replay report even when wall clock on a noisy CI box is not.
    Argument shape/dtype structs are captured on each variant's first
    call and the (potentially multi-second) ``lower().compile()`` for
    cost extraction is deferred to ``cost_report()`` — exit-report /
    regression-harness time, never the tick path.

Roofline context: ``set_peaks`` records the ``kernel_bench`` reference
rates (single-layer paged-attention tok/s and KV GB/s — layers run
sequentially, so the kernel's byte *rate* is also the model's ceiling),
and ``roofline()`` relates achieved rates to them."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class CompileEvent:
    """One observed jit compile (cache-size growth across a call)."""

    name: str                    # wrapped step's name ("unified_step")
    variant: str                 # shape-bucket label, e.g. "C=32,ens=False"
    t0: float                    # perf_counter at call start
    dur_s: float                 # wall duration of the compiling call
    post_warm: bool              # after the warmup boundary => regression

    def as_dict(self) -> dict:
        return {"name": self.name, "variant": self.variant,
                "dur_s": round(self.dur_s, 4), "post_warm": self.post_warm}


@dataclass
class _Variant:
    """Book-keeping for one (step, shape-signature) compile cell."""

    label: str
    jitted: object
    structs: Optional[tuple] = None      # ShapeDtypeStruct tree for lower()
    calls: int = 0
    compiles: int = 0
    cost: Optional[dict] = field(default=None)


def _cache_size(jitted) -> Optional[int]:
    """Compile-cache entry count of a ``jax.jit`` callable, None when the
    installed JAX doesn't expose it (detection then falls back to
    first-seen-signature, which catches new variants but not flushes)."""
    fn = getattr(jitted, "_cache_size", None)
    if fn is None:
        return None
    try:
        return int(fn())
    except Exception:
        return None


class StepProfiler:
    """Wraps jitted step callables; collects compile events + variant
    cost/call stats.  One per Telemetry; the engine wraps its steps at
    construction time."""

    def __init__(self, clock=time.perf_counter, max_events: int = 256):
        self.clock = clock
        self.max_events = max_events
        self.compile_events: List[CompileEvent] = []
        self.compiles_total = 0
        self.compiles_post_warm = 0
        self._warm = False
        self._variants: Dict[tuple, _Variant] = {}
        self.peaks: Dict[str, float] = {}
        # set by the owning Telemetry: routes each event to the timeline
        # span + anomaly monitor the moment the compile is observed
        self.on_compile: Optional[Callable[[CompileEvent], None]] = None

    # -- wrapping ------------------------------------------------------------
    def wrap(self, name: str, step_fn, key_fn=None):
        """Return a drop-in replacement for ``step_fn``.

        ``step_fn`` may be a plain jitted callable or the unified-step
        closure carrying a ``.variants`` dict of static-flag -> jitted
        (cache sizes are then watched per flag).  ``key_fn(args, kw)``
        labels the shape bucket; the default uses every top-level
        array argument's shape, which is cheap (no pytree walk) and
        distinguishes exactly what jit's shape specialisation does for
        the engine's steps."""
        variants = getattr(step_fn, "variants", None)

        def default_key(args, kw):
            shapes = tuple(tuple(a.shape) for a in args
                           if hasattr(a, "shape"))
            return shapes, ",".join("x".join(map(str, s)) for s in shapes)

        keyer = key_fn if key_fn is not None else default_key

        def wrapped(*args, **kw):
            jitted = variants[kw.get("ensembles", False)] \
                if variants is not None else step_fn
            sig, label = keyer(args, kw)
            key = (name, sig, tuple(sorted(kw.items())))
            rec = self._variants.get(key)
            before = _cache_size(jitted)
            t0 = self.clock()
            out = step_fn(*args, **kw)
            dur = self.clock() - t0
            if rec is None:
                rec = self._variants[key] = _Variant(
                    label=f"{name}[{label}]", jitted=jitted)
                try:
                    import jax
                    rec.structs = jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        args)
                    rec._kw = dict(kw)
                except Exception:
                    rec.structs = None
                new_variant = True
            else:
                new_variant = False
            rec.calls += 1
            after = _cache_size(jitted)
            compiled = (after > before) if before is not None \
                and after is not None else new_variant
            if compiled:
                self._record_compile(name, rec.label, t0, dur)
                rec.compiles += 1
            return out

        return wrapped

    def _record_compile(self, name: str, variant: str, t0: float,
                        dur_s: float) -> None:
        ev = CompileEvent(name, variant, t0, dur_s, self._warm)
        self.compiles_total += 1
        if self._warm:
            self.compiles_post_warm += 1
        if len(self.compile_events) < self.max_events:
            self.compile_events.append(ev)
        if self.on_compile is not None:
            self.on_compile(ev)

    def mark_warm(self) -> None:
        """Warmup boundary (``Engine.reset_stats``): compiles so far were
        expected; any compile from here on is a late compile — the
        regression signal.  A no-op until the wrapped step has actually
        run at least once: resetting a cold engine (e.g. a one-shot
        ``--replay`` on a fresh process) must not turn its very first
        compiles into alerts."""
        if any(rec.calls for rec in self._variants.values()):
            self._warm = True

    # -- cost attribution ----------------------------------------------------
    @staticmethod
    def _extract_cost(jitted, structs, kw) -> dict:
        """AOT-lower + compile the variant's captured arg structs and
        pull FLOPs / bytes-accessed.  ``cost_analysis()`` returns a dict
        on newer JAX, a one-element list of dicts on older backends."""
        import jax  # noqa: F401  (structs already imported it)
        lowered = jitted.lower(*structs) if not kw \
            else jitted.lower(*structs, **kw)
        cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost = cost or {}
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0))}

    def cost_report(self, compute: bool = True) -> dict:
        """Per-variant calls/compiles plus (lazily computed, memoised)
        ``cost_analysis`` numbers.  ``compute=False`` returns whatever is
        already memoised without paying any AOT compile — the shape
        ``Engine.metrics()`` uses on the hot stats-line path."""
        out: Dict[str, dict] = {}
        for rec in self._variants.values():
            entry = {"calls": rec.calls, "compiles": rec.compiles}
            if rec.cost is None and compute and rec.structs is not None:
                # the unified-step closure dispatches on a kwarg the
                # underlying jitted partial has already baked in, so
                # lower() takes the positional structs only
                try:
                    rec.cost = self._extract_cost(rec.jitted, rec.structs,
                                                  {})
                except Exception as e:          # pragma: no cover
                    rec.cost = {"error": f"{type(e).__name__}: {e}"[:200]}
            if rec.cost:
                entry.update(rec.cost)
            out[rec.label] = entry
        return out

    # -- roofline ------------------------------------------------------------
    def set_peaks(self, **peaks: float) -> None:
        """Reference rates from ``kernel_bench`` (e.g. ``kv_gb_s=...``,
        ``tok_s=...``); achieved-vs-peak gauges divide by these."""
        self.peaks.update({k: float(v) for k, v in peaks.items()
                           if v is not None})

    def roofline(self, achieved: Dict[str, float]) -> dict:
        """Relate achieved rates to the recorded peaks: for each metric
        present in both, emit the achieved value, the peak, and the
        fraction."""
        out = {}
        for k, v in achieved.items():
            entry = {"achieved": v}
            peak = self.peaks.get(k)
            if peak:
                entry["peak"] = peak
                entry["frac"] = v / peak
            out[k] = entry
        return out

    # -- lifecycle -----------------------------------------------------------
    def summary(self) -> dict:
        return {"compiles_total": self.compiles_total,
                "compiles_post_warm": self.compiles_post_warm,
                "variants": len(self._variants),
                "events": [e.as_dict() for e in self.compile_events]}

    def reset(self) -> None:
        """Drop events/counters but keep variant + cost memos (compile
        caches survive a stats reset, so should their attribution)."""
        self.compile_events.clear()
        self.compiles_total = 0
        self.compiles_post_warm = 0
