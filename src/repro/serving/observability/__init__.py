"""Serving observability: metrics registry, request lifecycle tracing,
per-tick Perfetto timelines, SLO attainment, traffic-trace
record/replay, device-step cost attribution, and live anomaly
detection — the single telemetry substrate the engine writes and
everything else (stats lines, benchmarks, CI gates, the regression
harness) reads.  See README "Observability" and "Continuous perf
harness"."""
from .anomaly import (ACCEPT_COLLAPSE, ALERT_KINDS, POOL_LEAK, RECOMPILE,
                      SLO_BURN, TICK_SPIKE, AcceptCollapseDetector, Alert,
                      AnomalyMonitor, BurnRateDetector, PoolLeakWatchdog,
                      TickSpikeDetector)
from .metrics import (DEFAULT_MAX_LABELS, OVERFLOW_LABEL, Counter, Gauge,
                      Histogram, MetricsRegistry, percentile,
                      percentile_or_none)
from .profiler import CompileEvent, StepProfiler
from .replay import (ReplayResult, TraceRecord, TraceRecorder, load_trace,
                     replay, save_trace, stream_digest)
from .slo import DEFAULT_CLASS, SLOClass, SLOTracker, parse_slo_class
from .stats import EngineStats
from .telemetry import Telemetry
from .trace import (ADMIT, EVENT_KINDS, FINISH, PREEMPT, PREFILL_CHUNK,
                    PREFIX_ADOPT, SPECULATE, SUBMIT, TICK_PHASES, TOKEN,
                    RequestTrace, RequestTracer, TickTimeline, TraceEvent,
                    validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "percentile_or_none",
    "DEFAULT_MAX_LABELS", "OVERFLOW_LABEL",
    "DEFAULT_CLASS", "SLOClass", "SLOTracker", "parse_slo_class",
    "EngineStats", "Telemetry",
    "SUBMIT", "ADMIT", "PREFIX_ADOPT", "PREFILL_CHUNK", "TOKEN",
    "SPECULATE", "PREEMPT", "FINISH", "EVENT_KINDS", "TICK_PHASES",
    "TraceEvent", "RequestTrace", "RequestTracer", "TickTimeline",
    "validate_chrome_trace",
    "Alert", "ALERT_KINDS", "TICK_SPIKE", "SLO_BURN", "POOL_LEAK",
    "ACCEPT_COLLAPSE", "RECOMPILE", "AnomalyMonitor", "TickSpikeDetector",
    "BurnRateDetector", "PoolLeakWatchdog", "AcceptCollapseDetector",
    "CompileEvent", "StepProfiler",
    "TraceRecord", "TraceRecorder", "ReplayResult",
    "load_trace", "save_trace", "replay", "stream_digest",
]
