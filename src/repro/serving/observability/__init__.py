"""Serving observability: metrics registry, request lifecycle tracing,
per-tick Perfetto timelines, and SLO attainment — the single telemetry
substrate the engine writes and everything else (stats lines,
benchmarks, CI gates) reads.  See README "Observability"."""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      percentile, percentile_or_none)
from .slo import DEFAULT_CLASS, SLOClass, SLOTracker, parse_slo_class
from .stats import EngineStats
from .telemetry import Telemetry
from .trace import (ADMIT, EVENT_KINDS, FINISH, PREEMPT, PREFILL_CHUNK,
                    PREFIX_ADOPT, SPECULATE, SUBMIT, TICK_PHASES, TOKEN,
                    RequestTrace, RequestTracer, TickTimeline, TraceEvent,
                    validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "percentile_or_none",
    "DEFAULT_CLASS", "SLOClass", "SLOTracker", "parse_slo_class",
    "EngineStats", "Telemetry",
    "SUBMIT", "ADMIT", "PREFIX_ADOPT", "PREFILL_CHUNK", "TOKEN",
    "SPECULATE", "PREEMPT", "FINISH", "EVENT_KINDS", "TICK_PHASES",
    "TraceEvent", "RequestTrace", "RequestTracer", "TickTimeline",
    "validate_chrome_trace",
]
