"""Metrics substrate for the serving stack: a ``MetricsRegistry`` of
counters, gauges, and streaming histograms, each with per-label views.

Design constraints, in order:

  * **Host-side and allocation-light.**  Every engine tick is one jitted
    device call; telemetry must never add a second one, and per-token
    bookkeeping must stay a dict lookup plus an add.  Histograms are
    log-bucketed (fixed count arrays), so p50/p90/p99 come without
    storing samples — a server that has decoded a billion tokens holds
    the same few hundred ints as one that decoded a thousand.
  * **Labels sum to totals.**  A labeled increment lands in both the
    per-label view and the aggregate, so ``sum(view().values()) ==
    value`` holds exactly whenever every increment carries a label
    (per-submodel token counts, per-class latency) — the invariant the
    tests pin.
  * **One percentile helper.**  ``percentile``/``percentile_or_none``
    replace the hand-rolled copies that used to live in
    ``launch/serve.py`` and ``benchmarks/serving_bench.py``; exact
    (sample-based) percentiles stay the ground truth for benchmark
    artifacts, histograms answer the streaming case.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from itertools import accumulate
from typing import Dict, Hashable, List, Optional

import numpy as np

# Per-metric label-view bound.  Long replays tag latency histograms and
# token counters with request-derived labels; without a cap a trace with
# a million distinct request ids grows a million dict entries per
# metric.  Labels beyond the cap fold into one explicit ``OVERFLOW``
# bucket — the labels-sum-to-totals invariant still holds exactly, the
# view just stops distinguishing the tail — and each fold bumps the
# registry's ``metrics.label_overflow`` warning counter (labeled by
# metric name) so the saturation is visible, not silent.
DEFAULT_MAX_LABELS = 64
OVERFLOW_LABEL = "overflow"


def percentile(xs, p: float, *, empty: float = float("nan")) -> float:
    """Exact percentile of a finite sample (numpy semantics), ``empty``
    when the sample is empty — the single shared helper for every
    launcher/benchmark percentile line."""
    xs = np.asarray(xs if isinstance(xs, np.ndarray) else list(xs))
    if xs.size == 0:
        return empty
    return float(np.percentile(xs, p))


def percentile_or_none(xs, p: float, ndigits: int = 4) -> Optional[float]:
    """``percentile`` rounded for JSON artifacts; None for an empty
    sample (JSON has no NaN)."""
    v = percentile(xs, p)
    return None if math.isnan(v) else round(v, ndigits)


class _LabelCap:
    """Shared label-routing for the three metric kinds: an unseen label
    past ``max_labels`` becomes ``OVERFLOW_LABEL`` (reserving one view
    slot for it), and the fold is reported to the registry's warning
    counter when one is attached."""

    __slots__ = ()

    def _route(self, label: Hashable) -> Hashable:
        if label is None or label in self._by_label:
            return label
        if len(self._by_label) >= max(self.max_labels - 1, 1) \
                and label != OVERFLOW_LABEL:
            self.label_overflows += 1
            if self._overflow_sink is not None:
                self._overflow_sink.inc(1.0, label=self.name)
            return OVERFLOW_LABEL
        return label


class Counter(_LabelCap):
    """Monotonic counter with an optional per-label breakdown."""

    __slots__ = ("name", "value", "_by_label", "max_labels",
                 "label_overflows", "_overflow_sink")

    def __init__(self, name: str, max_labels: int = DEFAULT_MAX_LABELS):
        self.name = name
        self.value = 0.0
        self._by_label: Dict[Hashable, float] = {}
        self.max_labels = max_labels
        self.label_overflows = 0
        self._overflow_sink = None

    def inc(self, n: float = 1.0, label: Hashable = None) -> None:
        self.value += n
        if label is not None:
            label = self._route(label)
            self._by_label[label] = self._by_label.get(label, 0.0) + n

    def view(self) -> Dict[Hashable, float]:
        return dict(self._by_label)

    def reset(self) -> None:
        self.value = 0.0
        self._by_label.clear()
        self.label_overflows = 0

    def summary(self) -> dict:
        out = {"type": "counter", "value": self.value}
        if self._by_label:
            out["by_label"] = self.view()
        if self.label_overflows:
            out["label_overflows"] = self.label_overflows
        return out


class Gauge(_LabelCap):
    """Point-in-time value (plus per-label values).  ``set_max`` keeps a
    running peak — the page-pool high-water marks."""

    __slots__ = ("name", "value", "_by_label", "max_labels",
                 "label_overflows", "_overflow_sink")

    def __init__(self, name: str, max_labels: int = DEFAULT_MAX_LABELS):
        self.name = name
        self.value = 0.0
        self._by_label: Dict[Hashable, float] = {}
        self.max_labels = max_labels
        self.label_overflows = 0
        self._overflow_sink = None

    def set(self, v: float, label: Hashable = None) -> None:
        if label is None:
            self.value = float(v)
        else:
            self._by_label[self._route(label)] = float(v)

    def set_max(self, v: float, label: Hashable = None) -> None:
        if label is None:
            self.value = max(self.value, float(v))
        else:
            label = self._route(label)
            if v > self._by_label.get(label, float("-inf")):
                self._by_label[label] = float(v)

    def view(self) -> Dict[Hashable, float]:
        return dict(self._by_label)

    def reset(self) -> None:
        self.value = 0.0
        self._by_label.clear()
        self.label_overflows = 0

    def summary(self) -> dict:
        out = {"type": "gauge", "value": self.value}
        if self._by_label:
            out["by_label"] = self.view()
        if self.label_overflows:
            out["label_overflows"] = self.label_overflows
        return out


class Histogram(_LabelCap):
    """Streaming histogram over geometric buckets: observations land in
    ``O(log)`` (a bisect over fixed edges), quantiles interpolate
    inside the covering bucket, and no sample is ever stored.  The
    relative quantile error is bounded by ``growth - 1`` per bucket
    (default ~7%), exact at the recorded min/max.  ``label`` routes the
    observation into a per-label child histogram as well as the
    aggregate, so label views sum to the total count.  Edges and counts
    are plain Python lists — a scalar ``np.searchsorted`` costs ~10x a
    ``bisect_left``, and ``observe`` sits on the engine's per-token
    path."""

    __slots__ = ("name", "_edges", "_counts", "count", "sum",
                 "min", "max", "_lo", "_hi", "_growth", "_by_label",
                 "max_labels", "label_overflows", "_overflow_sink")

    def __init__(self, name: str, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = 1.07,
                 max_labels: int = DEFAULT_MAX_LABELS):
        if not (0 < lo < hi) or growth <= 1.0:
            raise ValueError(f"bad histogram range ({lo}, {hi}, x{growth})")
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        self.name = name
        self._lo, self._hi, self._growth = lo, hi, growth
        self._edges: List[float] = \
            [lo * growth ** i for i in range(n + 1)]      # bucket uppers
        self._counts: List[int] = [0] * (n + 2)           # +under/overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._by_label: Dict[Hashable, "Histogram"] = {}
        self.max_labels = max_labels
        self.label_overflows = 0
        self._overflow_sink = None

    def observe(self, x: float, label: Hashable = None) -> None:
        x = float(x)
        self._counts[bisect_left(self._edges, x)] += 1
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if label is not None:
            label = self._route(label)
            child = self._by_label.get(label)
            if child is None:
                child = self._by_label[label] = Histogram(
                    f"{self.name}{{{label}}}", self._lo, self._hi,
                    self._growth)
            child.observe(x)

    def quantile(self, q: float) -> Optional[float]:
        """q in [0, 1]; None when empty."""
        if self.count == 0:
            return None
        target = max(q, 0.0) * self.count
        cum = list(accumulate(self._counts))
        i = bisect_left(cum, max(target, 1e-12))
        i = min(i, len(self._counts) - 1)
        lo = self._edges[i - 1] if i > 0 else self.min
        hi = self._edges[i] if i < len(self._edges) else self.max
        prev = cum[i - 1] if i > 0 else 0
        frac = (target - prev) / max(self._counts[i], 1)
        v = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return float(min(max(v, self.min), self.max))

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def view(self) -> Dict[Hashable, "Histogram"]:
        return dict(self._by_label)

    def reset(self) -> None:
        self._counts = [0] * len(self._counts)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._by_label.clear()
        self.label_overflows = 0

    def summary(self) -> dict:
        out = {
            "type": "histogram", "count": self.count,
            "sum": self.sum if self.count else 0.0,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean,
            "p50": self.quantile(0.50), "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }
        if self._by_label:
            out["by_label"] = {k: v.summary() for k, v in
                               self._by_label.items()}
        if self.label_overflows:
            out["label_overflows"] = self.label_overflows
        return out


class MetricsRegistry:
    """Name -> metric map with get-or-create constructors.  One registry
    per engine is the single read surface the stats line, the benchmark
    phases, and the SLO report all draw from."""

    # Name of the warning counter that records every label fold, labeled
    # by the saturated metric's name.
    OVERFLOW_COUNTER = "metrics.label_overflow"

    def __init__(self, max_labels: int = DEFAULT_MAX_LABELS):
        self._metrics: Dict[str, object] = {}
        self.max_labels = max_labels

    def _get(self, name: str, kind, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name, *args, **kw)
            if name != self.OVERFLOW_COUNTER:
                m._overflow_sink = self.counter(self.OVERFLOW_COUNTER)
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        # the overflow counter itself is never capped: it carries one
        # label per *metric name*, which the registry already bounds
        if name == self.OVERFLOW_COUNTER:
            return self._get(name, Counter, 2 ** 30)
        return self._get(name, Counter, self.max_labels)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, self.max_labels)

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 1e4,
                  growth: float = 1.07) -> Histogram:
        return self._get(name, Histogram, lo, hi, growth,
                         self.max_labels)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self):
        return sorted(self._metrics)

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> Dict[str, dict]:
        """Every registered metric, summarized — the registry's one
        export format (the stats line, the bench JSON, and the README
        metrics catalog all read this shape)."""
        return {name: self._metrics[name].summary()
                for name in sorted(self._metrics)}
