"""FCFS continuous-batching scheduler: admission queue + decode-slot lifecycle.

Requests wait in arrival order; a request joins the running batch as soon as
a decode slot is free AND the page pool can cover it under the admission
policy.  Slots are evicted the moment a request finishes (max_new_tokens or
EOS), so the next waiting request joins mid-flight — no batch barrier.

Admission policies:
  "reserve"    allocate worst-case pages (prompt + max_new) up front; decode
               can never OOM the pool (throughput-conservative, vLLM-v0
               style reservation).
  "on_demand"  allocate prompt pages (+1 token of headroom) only; pages are
               pulled from the free list as sequences grow.  Higher packing,
               but a pathological mix can exhaust the pool mid-decode —
               callers must handle PagePoolOOM (the engine turns it into a
               clean EngineOOM; preemption is a ROADMAP follow-on).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.kv_cache import PagePool


@dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""

    id: int
    prompt: np.ndarray                  # [len] int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None

    # runtime (engine/scheduler-owned)
    slot: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def context_len(self) -> int:
        return self.prompt_len + len(self.out_tokens)

    @property
    def finished(self) -> bool:
        if self.out_tokens and self.eos_id is not None \
                and self.out_tokens[-1] == self.eos_id:
            return True
        return len(self.out_tokens) >= self.max_new_tokens


class FCFSScheduler:
    """First-come-first-served admission into ``num_slots`` decode slots."""

    def __init__(self, num_slots: int, pool: PagePool, *,
                 policy: str = "reserve"):
        if policy not in ("reserve", "on_demand"):
            raise ValueError(policy)
        self.num_slots = num_slots
        self.pool = pool
        self.policy = policy
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}       # slot -> request
        self._free_slots = list(range(num_slots - 1, -1, -1))
        self.finished: List[Request] = []

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def admission_pages(self, req: Request) -> int:
        """Pages the policy demands free before ``req`` may join."""
        if self.policy == "reserve":
            return self.pool.pages_for(req.prompt_len + req.max_new_tokens)
        return self.pool.pages_for(req.prompt_len + 1)

    # -- lifecycle ----------------------------------------------------------
    def admit(self, now: float) -> List[Request]:
        """Move FCFS-head requests into free slots while the pool allows.
        Strict FCFS: if the head doesn't fit, nothing behind it jumps the
        queue (no head-of-line bypass — keeps latency ordering honest)."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            if not self.pool.can_alloc(self.admission_pages(req)):
                break
            self.waiting.popleft()
            req.slot = self._free_slots.pop()
            req.t_admitted = now
            self.pool.alloc(req.id, self.admission_pages(req)
                            * self.pool.page_size)
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def grow(self, req: Request) -> List[int]:
        """Make sure ``req`` has pages through its current context length
        (the next decode step writes at position context_len - 1).  Only the
        on_demand policy ever allocates here; reserve is already covered."""
        return self.pool.ensure(req.id, req.context_len)

    def record_token(self, slot: int, token: int, now: float) -> None:
        req = self.running[slot]
        if not req.out_tokens:
            req.t_first_token = now
        req.out_tokens.append(token)

    def evict_finished(self, now: float) -> List[Request]:
        """Free slots + pages of every finished running request."""
        done = []
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.finished:
                req.t_done = now
                del self.running[slot]
                self._free_slots.append(slot)
                self.pool.free_seq(req.id)
                req.slot = None
                done.append(req)
        self.finished.extend(done)
        return done
