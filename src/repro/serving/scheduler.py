"""FCFS continuous-batching scheduler: admission queue + slot lifecycle +
preemption.

Requests wait in arrival order; a request joins the running batch as soon as
a slot is free AND the page pool can cover it under the admission policy.
Admitted requests stream their prompt into the page pool in token-budget
chunks (the engine's unified tick), then decode; slots are evicted the
moment a request finishes, so the next waiting request joins mid-flight —
no batch barrier.

Admission policies:
  "reserve"    allocate worst-case pages (prompt + max_new) up front; decode
               can never OOM the pool (throughput-conservative, vLLM-v0
               style reservation).
  "on_demand"  allocate prompt pages (+1 token of headroom) only; pages are
               pulled from the free list as sequences grow.  Higher packing;
               when a pathological mix exhausts the pool mid-decode the
               engine *preempts* the youngest running sequence back to the
               head of the waiting queue (pages freed, KV recomputed on
               re-admission through the same chunked-prefill path) instead
               of dying — throughput degrades, the server survives.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.kv_cache import PagePool


@dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""

    id: int
    prompt: np.ndarray                  # [len] int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None
    submodel_id: int = 0                # which ModelBank circuit serves this
    group: Optional["EnsembleGroup"] = None   # set for ensemble members

    # runtime (engine/scheduler-owned)
    slot: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    prefill_pos: int = 0                # kv_tokens already written to pages
    admit_seq: int = -1                 # global admission order (preemption
                                        # evicts the youngest = max admit_seq)
    num_preemptions: int = 0
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def context_len(self) -> int:
        return self.prompt_len + len(self.out_tokens)

    @property
    def num_kv_tokens(self) -> int:
        """Tokens whose KV must be in pages before decode can proceed: the
        prompt plus every generated token except the last (whose KV is
        written by the decode step that consumes it)."""
        return self.prompt_len + max(0, len(self.out_tokens) - 1)

    @property
    def kv_tokens(self) -> np.ndarray:
        """The token stream chunked prefill feeds through the pool.  For a
        fresh request this is the prompt; after a preemption it also carries
        the already-generated tokens, so re-admission rebuilds the exact KV
        state the sequence had when evicted."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens[:-1], np.int32)])

    @property
    def in_prefill(self) -> bool:
        """Still streaming prompt (or recomputed) KV into pages; a fresh
        request stays in prefill until its first token is sampled."""
        return self.prefill_pos < self.num_kv_tokens or not self.out_tokens

    @property
    def finished(self) -> bool:
        if self.out_tokens and self.eos_id is not None \
                and self.out_tokens[-1] == self.eos_id:
            return True
        return len(self.out_tokens) >= self.max_new_tokens


@dataclass
class EnsembleGroup:
    """One prompt fanned across every circuit of a ModelBank (paper §2's
    collective ensemble at inference): G member requests, one per submodel,
    advance in lockstep and share one combined token stream.

    Members are scheduled as an atomic unit — admitted together (G slots +
    pages for every member, or none), preempted together, finished together.
    Per-step logits are combined *on device* inside the unified step
    (``combine``: mean of member logits, or a majority vote over member
    samples), so every member records the same token and their KV states
    stay consistent with the shared stream.  Member KV pages are NOT shared:
    each circuit's masked weights produce different K/V for the same tokens
    (pages could only be shared between circuits with identical masks)."""

    id: int
    combine: str                        # "mean_logit" | "majority_vote"
    members: List[Request] = field(default_factory=list)

    @property
    def leader(self) -> Request:
        return self.members[0]

    @property
    def out_tokens(self) -> List[int]:
        return self.leader.out_tokens

    @property
    def finished(self) -> bool:
        return all(m.finished for m in self.members)


def _unit(req: Request) -> List[Request]:
    """The atomic scheduling unit ``req`` belongs to (its whole ensemble
    group, or just itself)."""
    return req.group.members if req.group is not None else [req]


class FCFSScheduler:
    """First-come-first-served admission into ``num_slots`` decode slots."""

    def __init__(self, num_slots: int, pool: PagePool, *,
                 policy: str = "reserve"):
        if policy not in ("reserve", "on_demand"):
            raise ValueError(policy)
        self.num_slots = num_slots
        self.pool = pool
        self.policy = policy
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}       # slot -> request
        self._free_slots = list(range(num_slots - 1, -1, -1))
        self._admit_counter = 0
        self.finished: List[Request] = []
        self.preemptions = 0

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def admission_pages(self, req: Request) -> int:
        """Pages the policy demands free before ``req`` may join.  For a
        preempted request re-admitting, ``num_kv_tokens`` carries the grown
        context, so on_demand re-reserves everything its recomputed KV (+1
        token of headroom) needs."""
        if self.policy == "reserve":
            return self.pool.pages_for(req.prompt_len + req.max_new_tokens)
        return self.pool.pages_for(req.num_kv_tokens + 1)

    # -- lifecycle ----------------------------------------------------------
    def admit(self, now: float) -> List[Request]:
        """Move FCFS-head requests into free slots while the pool allows.
        Strict FCFS: if the head doesn't fit, nothing behind it jumps the
        queue (no head-of-line bypass — keeps latency ordering honest).
        Ensemble groups admit atomically: the whole unit needs a slot and
        pages for every member, or nothing moves."""
        admitted = []
        while self.waiting and self._free_slots:
            unit = _unit(self.waiting[0])
            if len(unit) > len(self._free_slots):
                break
            # group members sit contiguously at the queue head (submitted
            # together; preemption pushes the whole unit back together)
            assert all(self.waiting[i] is r for i, r in enumerate(unit)), \
                "ensemble members not contiguous at queue head"
            needs = [self.admission_pages(r) for r in unit]
            if not self.pool.can_alloc(sum(needs)):
                break
            for req, need in zip(unit, needs):
                self.waiting.popleft()
                req.slot = self._free_slots.pop()
                req.t_admitted = now
                req.admit_seq = self._admit_counter
                self._admit_counter += 1
                req.prefill_pos = 0
                self.pool.alloc_pages(req.id, need, owner=req.submodel_id)
                self.running[req.slot] = req
                admitted.append(req)
        return admitted

    def grow(self, req: Request) -> List[int]:
        """Make sure ``req`` has pages through its current context length
        (the next decode step writes at position context_len - 1).  Only the
        on_demand policy ever allocates here; reserve is already covered.
        Raises PagePoolOOM on pool pressure — the engine answers by
        preempting the youngest running sequence and retrying."""
        return self.pool.ensure(req.id, req.context_len)

    def preempt_youngest(self) -> Optional[Request]:
        """Evict the most recently admitted running scheduling unit (a solo
        sequence, or a whole ensemble group) back to the HEAD of the waiting
        queue: its pages return to the free list and its KV is recomputed on
        re-admission via chunked prefill.  Returns the victim (a group's
        leader), or None when fewer than two units run (evicting the sole
        survivor could never free pages for it — that is a genuine,
        unservable OOM the engine must surface)."""
        units: Dict[int, List[Request]] = {}      # keyed by leader id
        for req in self.running.values():
            units.setdefault(_unit(req)[0].id, _unit(req))
        if len(units) < 2:
            return None
        victims = max(units.values(),
                      key=lambda u: max(r.admit_seq for r in u))
        self.preemptions += 1
        # appendleft keeps FCFS order when several preemptions stack up in
        # one tick: younger victims are pushed first and end up behind the
        # older ones preempted after them; reversed() keeps a group's
        # members in member order at the head
        for victim in reversed(victims):
            del self.running[victim.slot]
            self._free_slots.append(victim.slot)
            self.pool.free_seq(victim.id)
            victim.slot = None
            victim.prefill_pos = 0
            victim.num_preemptions += 1
            self.waiting.appendleft(victim)
        return victims[0]

    def record_token(self, slot: int, token: int, now: float) -> None:
        req = self.running[slot]
        if not req.out_tokens:
            req.t_first_token = now
        req.out_tokens.append(token)

    def evict_finished(self, now: float) -> List[Request]:
        """Free slots + pages of every finished running request."""
        done = []
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.finished:
                req.t_done = now
                del self.running[slot]
                self._free_slots.append(slot)
                self.pool.free_seq(req.id)
                req.slot = None
                done.append(req)
        self.finished.extend(done)
        return done
