"""FCFS continuous-batching scheduler: admission queue + slot lifecycle +
preemption, prefix-cache-aware.

Requests wait in arrival order; a request joins the running batch as soon as
a slot is free AND the page pool can cover it under the admission policy.
Admitted requests stream their prompt into the page pool in token-budget
chunks (the engine's unified tick), then decode; slots are evicted the
moment a request finishes, so the next waiting request joins mid-flight —
no batch barrier.

Admission consults the pool's prefix cache first: the longest cached
page-prefix of the prompt is *adopted* (refcount + 1 per page, zero fresh
pages, zero prefill compute) and chunked prefill starts at
``num_cached_tokens`` — only the uncached tail is sized, allocated, and
computed.  Preemption releases page *references* (``free_seq`` decrements
refcounts); physical pages return to the free list — or are held by the
prefix cache — only when the last reference drops.

Admission policies:
  "reserve"    allocate worst-case pages (prompt + max_new, minus the
               cached prefix) up front; decode can never OOM the pool
               (throughput-conservative, vLLM-v0 style reservation).
               Shared-prefill ensemble members cannot position-map their
               tail pages until they fork off the leader's prompt pages,
               so their worst case is *promised* at admission (deferred
               credits the pool charges against every later allocation)
               and redeemed at fork/COW time.
  "on_demand"  allocate prompt pages (+1 token of headroom) only; pages are
               pulled from the free list as sequences grow.  Higher packing;
               when a pathological mix exhausts the pool mid-decode the
               engine *preempts* the youngest running sequence back to the
               head of the waiting queue (references released, KV recomputed
               on re-admission through the same chunked-prefill path)
               instead of dying — throughput degrades, the server survives.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.kv_cache import PagePool, chain_hashes


@dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""

    id: int
    prompt: np.ndarray                  # [len] int32 token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None
    submodel_id: int = 0                # which ModelBank circuit serves this
    group: Optional["EnsembleGroup"] = None   # set for ensemble members
    kv_namespace: bytes = b"dense"      # content-hash namespace: which
                                        # encoder produced this KV (engine
                                        # sets b"sub:g" for routed requests)
    mask_from: int = 0                  # first position the circuit masks
                                        # apply at (ensemble members share a
                                        # dense-encoded prompt context
                                        # [0, mask_from); solo requests: 0)
    slo_class: str = "default"          # SLO priority class (observability/
                                        # slo.py) the finished request is
                                        # scored under

    # runtime (engine/scheduler-owned)
    slot: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    prefill_pos: int = 0                # kv_tokens already written to pages
    admit_seq: int = -1                 # global admission order (preemption
                                        # evicts the youngest = max admit_seq)
    num_preemptions: int = 0
    num_cached_tokens: int = 0          # prefix-cache hit at last admission
    cache_eligible_tokens: int = 0      # tokens the lookup could have matched
    page_hashes: List[bytes] = field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    t_preempted: Optional[float] = None  # last preemption (engine clock)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def context_len(self) -> int:
        return self.prompt_len + len(self.out_tokens)

    @property
    def num_kv_tokens(self) -> int:
        """Tokens whose KV must be in pages before decode can proceed: the
        prompt plus every generated token except the last (whose KV is
        written by the decode step that consumes it)."""
        return self.prompt_len + max(0, len(self.out_tokens) - 1)

    @property
    def kv_tokens(self) -> np.ndarray:
        """The token stream chunked prefill feeds through the pool.  For a
        fresh request this is the prompt; after a preemption it also carries
        the already-generated tokens, so re-admission rebuilds the exact KV
        state the sequence had when evicted."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens[:-1], np.int32)])

    @property
    def publishable_end(self) -> int:
        """Tokens of ``kv_tokens`` whose pages may be content-indexed
        under ``kv_namespace``.  An ensemble member's stream is dense-
        encoded only up to ``mask_from`` (its masked tail is private to
        the member's circuit); a solo stream is uniformly encoded."""
        return self.mask_from if self.group is not None \
            else self.num_kv_tokens

    @property
    def match_cap(self) -> int:
        """Tokens a prefix-cache lookup may cover at admission.  A fresh
        request must recompute at least its last prompt token — the chunk
        that completes prefill yields the first sampled token; a preempted
        request's next token is already known, so its whole recompute
        stream is fair game (capped at the publishable region)."""
        if self.group is not None:
            return self.mask_from
        if self.out_tokens:
            return self.num_kv_tokens
        return self.prompt_len - 1

    @property
    def spec_eligible(self) -> bool:
        """May a draft circuit speculate for this request this tick?
        Decode-phase solo (or routed) requests only: ensemble members
        advance in lockstep through on-device logit combining, so a
        per-member draft tail would have to be accepted by the *combined*
        distribution — they decode one token per tick instead."""
        return self.group is None and not self.in_prefill

    @property
    def in_prefill(self) -> bool:
        """Still streaming prompt (or recomputed) KV into pages; a fresh
        request stays in prefill until its first token is sampled."""
        return self.prefill_pos < self.num_kv_tokens or not self.out_tokens

    @property
    def finished(self) -> bool:
        if self.out_tokens and self.eos_id is not None \
                and self.out_tokens[-1] == self.eos_id:
            return True
        return len(self.out_tokens) >= self.max_new_tokens


@dataclass
class EnsembleGroup:
    """One prompt fanned across every circuit of a ModelBank (paper §2's
    collective ensemble at inference): G member requests, one per submodel,
    advance in lockstep and share one combined token stream.

    Members are scheduled as an atomic unit — admitted together (slots +
    pages for every member, or none), preempted together, finished together.
    Per-step logits are combined *on device* inside the unified step
    (``combine``: mean of member logits, or a majority vote over member
    samples), so every member records the same token and their KV states
    stay consistent with the shared stream.

    The prompt *context* — attention K/V for positions [0, prompt_len - 1)
    — is encoded by the dense parent (circuit masks engage from
    ``mask_from`` = prompt_len - 1 onward: each member encodes the last
    prompt token and its decode tail through its own masked FFNs), so the
    context is byte-identical across members by construction.  With
    ``share`` set (engine prefix cache on) it is therefore computed ONCE:
    the leader prefills it, members fork the leader's prompt pages
    (refcount G) and only their per-member tails copy-on-write on
    divergence.  With ``share`` unset every member re-prefills the same
    bytes into private pages — the compatibility path the parity tests
    compare against."""

    id: int
    combine: str                        # "mean_logit" | "majority_vote"
    members: List[Request] = field(default_factory=list)
    share: bool = False                 # prefill the shared context once
    forked: bool = False                # members mapped the leader's pages

    @property
    def leader(self) -> Request:
        return self.members[0]

    @property
    def out_tokens(self) -> List[int]:
        return self.leader.out_tokens

    @property
    def finished(self) -> bool:
        return all(m.finished for m in self.members)


def _unit(req: Request) -> List[Request]:
    """The atomic scheduling unit ``req`` belongs to (its whole ensemble
    group, or just itself)."""
    return req.group.members if req.group is not None else [req]


def speculative_draft_len(k: int, budget: int, n_decode: int,
                          n_spec: int) -> int:
    """Uniform per-tick draft length for the tick's speculating slots.

    A speculating slot consumes ``1 + draft_len`` tokens of the tick's
    budget — the budget meters *parent* compute, so it counts the tokens
    the parent verifies (the pending token plus every draft), never the
    tokens the draft circuit generated to propose them.  Every decode slot
    (speculating or not) costs its one pending token first; whatever
    remains is split evenly across the speculating slots so the tick keeps
    a single verify window width.  Clamped to [0, k]; 0 degrades the tick
    to plain decode (budget exhausted by the decode batch itself)."""
    if n_spec <= 0 or k <= 0:
        return 0
    return max(0, min(k, (budget - n_decode) // n_spec))


@dataclass
class _AdmissionPlan:
    """Sized admission for one request of a unit."""
    req: Request
    cached: List[int]                   # prefix-cache pages to adopt
    cached_tokens: int
    fresh: int                          # pages to allocate now
    deferred: int                       # pages to promise (reserve members)
    hashes: List[bytes]                 # content ids for publish_prefix
    probed: int = 0                     # hashes the cache lookup walked over


class FCFSScheduler:
    """First-come-first-served admission into ``num_slots`` decode slots."""

    def __init__(self, num_slots: int, pool: PagePool, *,
                 policy: str = "reserve"):
        if policy not in ("reserve", "on_demand"):
            raise ValueError(policy)
        self.num_slots = num_slots
        self.pool = pool
        self.policy = policy
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}       # slot -> request
        self._free_slots = list(range(num_slots - 1, -1, -1))
        self._admit_counter = 0
        self.finished: List[Request] = []
        self.preemptions = 0

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- admission sizing ----------------------------------------------------
    @staticmethod
    def _is_shared_member(req: Request) -> bool:
        """True for a non-leader member of a share-mode ensemble: it maps
        the leader's prompt pages at fork time instead of allocating its
        own."""
        g = req.group
        return g is not None and g.share and req is not g.leader

    def _worst_case_pages(self, req: Request) -> int:
        """Pages the policy wants covered for ``req`` ignoring cache hits.
        For a preempted request re-admitting, ``num_kv_tokens`` carries the
        grown context, so on_demand re-reserves everything its recomputed
        KV (+1 token of headroom) needs."""
        if self.policy == "reserve":
            return self.pool.pages_for(req.prompt_len + req.max_new_tokens)
        return self.pool.pages_for(req.num_kv_tokens + 1)

    def admission_pages(self, req: Request) -> int:
        """Pages the policy demands available before ``req`` may join,
        assuming no prefix-cache hit (the worst case — feasibility checks
        use this).  A shared-prefill ensemble member only ever owns its
        tail: the shared full prompt pages are the leader's."""
        need = self._worst_case_pages(req)
        if self._is_shared_member(req):
            need = max(0, need - req.mask_from // self.pool.page_size)
        return need

    def unit_admission_pages(self, unit: List[Request]) -> int:
        """Worst-case pages the whole scheduling unit needs available to
        admit (no cache hits)."""
        return sum(self.admission_pages(r) for r in unit)

    def _plan_admission(self, unit: List[Request]) -> List[_AdmissionPlan]:
        """Size every request of a unit against the pool's prefix cache:
        cached prompt pages are adopted, only the uncached tail is
        allocated fresh, and shared-prefill member tails are deferred
        (reserve) or grown lazily (on_demand).

        Lookups here are non-promoting *peeks*: a blocked FCFS head replans
        every tick, and counting each retry as a cache hit (or letting it
        refresh LRU recency) would keep stale pages hot and inflate the hit
        rate — stats are committed only when ``admit`` actually adopts the
        plan (the negative cache still short-circuits known-cold walks)."""
        plans = []
        P = self.pool.page_size
        for req in unit:
            if self._is_shared_member(req):
                deferred = self.admission_pages(req) \
                    if self.policy == "reserve" else 0
                plans.append(_AdmissionPlan(req, [], 0, 0, deferred, []))
                continue
            # the chain is deterministic per (namespace, stream prefix) and
            # streams only ever append, so reuse the hashes from a previous
            # attempt (a blocked FCFS head replans every tick) unless a
            # preemption grew the publishable region since
            hashes = req.page_hashes
            if len(hashes) != req.publishable_end // P:
                hashes = chain_hashes(
                    req.kv_namespace,
                    np.asarray(req.kv_tokens[:req.publishable_end],
                               np.int32), P)
                req.page_hashes = hashes
            cap = req.match_cap
            probe = hashes[:cap // P]
            cached = self.pool.match_pages(probe, peek=True) \
                if self.pool.cache is not None else []
            fresh = max(0, self._worst_case_pages(req) - len(cached))
            plans.append(_AdmissionPlan(req, cached, len(cached) * P,
                                        fresh, 0, hashes, len(probe)))
        return plans

    # -- lifecycle ----------------------------------------------------------
    def admit(self, now: float) -> List[Request]:
        """Move FCFS-head requests into free slots while the pool allows.
        Strict FCFS: if the head doesn't fit, nothing behind it jumps the
        queue (no head-of-line bypass — keeps latency ordering honest).
        Ensemble groups admit atomically: the whole unit needs a slot and
        pages for every member, or nothing moves."""
        admitted = []
        while self.waiting and self._free_slots:
            unit = _unit(self.waiting[0])
            if len(unit) > len(self._free_slots):
                break
            # group members sit contiguously at the queue head (submitted
            # together; preemption pushes the whole unit back together)
            assert all(self.waiting[i] is r for i, r in enumerate(unit)), \
                "ensemble members not contiguous at queue head"
            plans = self._plan_admission(unit)
            pinned = frozenset(p for pl in plans for p in pl.cached)
            need = sum(pl.fresh + pl.deferred for pl in plans)
            if not self.pool.can_alloc(need, pinned=pinned):
                break
            for pl in plans:
                req = pl.req
                self.waiting.popleft()
                req.slot = self._free_slots.pop()
                req.t_admitted = now
                req.admit_seq = self._admit_counter
                self._admit_counter += 1
                req.prefill_pos = pl.cached_tokens
                req.num_cached_tokens = pl.cached_tokens
                req.cache_eligible_tokens = \
                    0 if self._is_shared_member(req) else req.match_cap
                req.page_hashes = pl.hashes
                if pl.probed:      # adoption commits the peeked lookup
                    self.pool.commit_match(len(pl.cached),
                                           len(pl.cached) < pl.probed)
                self.pool.alloc_pages(req.id, pl.fresh,
                                      owner=req.submodel_id,
                                      cached=pl.cached, deferred=pl.deferred)
                self.running[req.slot] = req
                admitted.append(req)
        return admitted

    def fork_group(self, group: EnsembleGroup) -> int:
        """Map the leader's shared prompt pages — the dense-encoded context
        [0, mask_from) — into every other member's table (refcount + 1 per
        page; the trailing partial page copy-on-writes when the member's
        masked tail first touches it).  Members resume prefill at
        ``mask_from``: their masked last prompt token + decode tail is all
        they ever compute.  Returns prefill tokens saved vs. the
        re-prefill path."""
        leader = group.leader
        n_shared = self.pool.pages_for(leader.mask_from)
        shared = self.pool.table(leader.id)[:n_shared]
        saved = 0
        for m in group.members[1:]:
            self.pool.adopt_prefix(m.id, shared)
            m.prefill_pos = m.mask_from
            saved += m.mask_from
        group.forked = True
        return saved

    def preempt_youngest(self) -> Optional[Request]:
        """Evict the most recently admitted running scheduling unit (a solo
        sequence, or a whole ensemble group) back to the HEAD of the waiting
        queue: its page references are released (shared pages survive under
        their other holders; exclusive pages go back to the free list or
        the prefix cache) and its KV is recomputed on re-admission via
        chunked prefill.  Returns the victim (a group's leader), or None
        when fewer than two units run (evicting the sole survivor could
        never free pages for it — that is a genuine, unservable OOM the
        engine must surface)."""
        units: Dict[int, List[Request]] = {}      # keyed by leader id
        for req in self.running.values():
            units.setdefault(_unit(req)[0].id, _unit(req))
        if len(units) < 2:
            return None
        victims = max(units.values(),
                      key=lambda u: max(r.admit_seq for r in u))
        self.preemptions += 1
        # appendleft keeps FCFS order when several preemptions stack up in
        # one tick: younger victims are pushed first and end up behind the
        # older ones preempted after them; reversed() keeps a group's
        # members in member order at the head
        for victim in reversed(victims):
            del self.running[victim.slot]
            self._free_slots.append(victim.slot)
            self.pool.free_seq(victim.id)
            victim.slot = None
            victim.prefill_pos = 0
            victim.num_preemptions += 1
            self.waiting.appendleft(victim)
        if victims[0].group is not None:
            victims[0].group.forked = False
        return victims[0]

    def record_token(self, slot: int, token: int, now: float) -> None:
        req = self.running[slot]
        if not req.out_tokens:
            req.t_first_token = now
        req.out_tokens.append(token)

    def evict_finished(self, now: float) -> List[Request]:
        """Free slots + page references of every finished running request."""
        done = []
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.finished:
                req.t_done = now
                del self.running[slot]
                self._free_slots.append(slot)
                self.pool.free_seq(req.id)
                req.slot = None
                done.append(req)
        self.finished.extend(done)
        return done
