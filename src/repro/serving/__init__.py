"""Continuous-batching serving engine (paged KV cache + FCFS scheduler),
multi-tenant across Horn's parallel circuits.

Layering (each importable on its own):

  kv_cache.py    host-side page-pool bookkeeping: free list, per-sequence
                 page tables, page refcounts with copy-on-write, a
                 content-addressed PrefixCache (rolling hash chained per
                 token block; LRU of retired full pages), and utilization
                 accounting attributable to an owner tag (the submodel a
                 sequence is routed to).  Pure Python — the device-side
                 pools live in the model cache pytree.
  scheduler.py   FCFS admission queue + slot lifecycle (join on admission,
                 evict on completion / max length, preempt-youngest on pool
                 pressure).  Admission adopts the longest cached
                 page-prefix so chunked prefill starts mid-prompt; an
                 ensemble's shared (dense-encoded) prompt context is
                 prefilled once by the leader and forked (refcount G) into
                 every member.  Ensemble groups are atomic scheduling
                 units.
  model_bank.py  G fixed Horn sub-models of one parent (per-layer block
                 masks drawn once from core/submodel.plan; shared weights,
                 shared page pool); materialize exports a circuit as
                 physically smaller weights.
  router.py      tags each request with a submodel_id: explicit id,
                 hash-affinity, or least-loaded.
  speculative.py DraftRunner: a materialized small circuit
                 (ModelBank.draft_model) proposes K tokens per decode tick
                 in one jitted call (catch-up chunk + on-device scan)
                 against its own never-OOM page pool; the engine's unified
                 step verifies all K+1 positions per slot in the same
                 budgeted call and rolls rejected tails back by
                 ref-release.
  observability/ the telemetry substrate every other layer writes into and
                 every consumer reads from: MetricsRegistry (counters /
                 gauges / streaming histograms with per-label views),
                 EngineStats (the engine's counter dataclass),
                 RequestTracer (typed lifecycle events on the engine
                 clock), TickTimeline (per-tick phase spans -> Chrome
                 Trace Event JSON for Perfetto), SLOTracker (per-class
                 TTFT/latency attainment).  Host-side only.
  engine.py      ties them to the model: one unified token-budget tick per
                 step — decode tokens and chunked-prefill prompt chunks
                 from ALL sub-models share a single jitted call that
                 appends K/V to the page pool, runs chunked paged
                 attention under per-slot gathered circuit masks, combines
                 ensemble-group logits on device (mean-logit / majority
                 vote), and samples every slot's next token on device;
                 latency/TTFT accounting; incremental block-table row sync.

The device kernel behind it is ``repro.kernels.paged_attention``
(``paged_chunk_attention``: decode rides as chunk width 1).
"""
from repro.serving.engine import Engine, EngineConfig, EngineOOM
from repro.serving.kv_cache import (PagePool, PagePoolOOM, PrefixCache,
                                    chain_hashes)
from repro.serving.model_bank import DraftModel, ModelBank
from repro.serving.observability import (EngineStats, MetricsRegistry,
                                         RequestTracer, SLOClass, SLOTracker,
                                         Telemetry, TickTimeline,
                                         parse_slo_class, percentile,
                                         validate_chrome_trace)
from repro.serving.router import Router
from repro.serving.scheduler import (EnsembleGroup, FCFSScheduler, Request,
                                     speculative_draft_len)
from repro.serving.speculative import DraftRunner

__all__ = ["DraftModel", "DraftRunner", "Engine", "EngineConfig",
           "EngineOOM", "EngineStats", "EnsembleGroup", "FCFSScheduler",
           "MetricsRegistry", "ModelBank", "PagePool", "PagePoolOOM",
           "PrefixCache", "Request", "RequestTracer", "Router", "SLOClass",
           "SLOTracker", "Telemetry", "TickTimeline", "chain_hashes",
           "parse_slo_class", "percentile", "speculative_draft_len",
           "validate_chrome_trace"]
