"""Continuous-batching serving engine (paged KV cache + FCFS scheduler).

Layering (each importable on its own):

  kv_cache.py   host-side page-pool bookkeeping: free list, per-sequence
                page tables, utilization accounting.  Pure Python — the
                device-side pools live in the model cache pytree.
  scheduler.py  FCFS admission queue + decode-slot lifecycle (join on
                admission, evict on completion / max length).
  engine.py     ties them to the model: bucketed batch-1 prefill scattered
                into pages, one fused paged-decode step per tick, per-request
                sampling keys, latency/TTFT accounting.

The device kernel behind it is ``repro.kernels.paged_attention``.
"""
from repro.serving.engine import Engine, EngineConfig, EngineOOM
from repro.serving.kv_cache import PagePool, PagePoolOOM
from repro.serving.scheduler import FCFSScheduler, Request

__all__ = ["Engine", "EngineConfig", "EngineOOM", "PagePool", "PagePoolOOM",
           "FCFSScheduler", "Request"]
