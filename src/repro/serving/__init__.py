"""Continuous-batching serving engine (paged KV cache + FCFS scheduler).

Layering (each importable on its own):

  kv_cache.py   host-side page-pool bookkeeping: free list, per-sequence
                page tables, utilization accounting.  Pure Python — the
                device-side pools live in the model cache pytree.
  scheduler.py  FCFS admission queue + slot lifecycle (join on admission,
                evict on completion / max length, preempt-youngest on pool
                pressure).
  engine.py     ties them to the model: one unified token-budget tick per
                step — decode tokens and chunked-prefill prompt chunks share
                a single jitted call that appends K/V to the page pool,
                runs chunked paged attention, and samples every slot's next
                token on device; latency/TTFT accounting.

The device kernel behind it is ``repro.kernels.paged_attention``
(``paged_chunk_attention``: decode rides as chunk width 1).
"""
from repro.serving.engine import Engine, EngineConfig, EngineOOM
from repro.serving.kv_cache import PagePool, PagePoolOOM
from repro.serving.scheduler import FCFSScheduler, Request

__all__ = ["Engine", "EngineConfig", "EngineOOM", "PagePool", "PagePoolOOM",
           "FCFSScheduler", "Request"]
