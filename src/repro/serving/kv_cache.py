"""Host-side paged KV-cache bookkeeping: free-list page pool + page tables.

The device-side KV pools (``transformer.init_paged_cache``) are plain arrays
[num_pages, page_size, KH, D]; this module decides *which* page ids a
sequence owns.  Page ids are layer-agnostic — one allocation covers every
layer's pool, so the free list is a single flat structure regardless of
depth.  Page 0 is reserved as the null page: empty decode slots point their
block-table rows at it and their garbage writes land there harmlessly.

Allocations carry an optional *owner* tag (the serving engine passes the
request's submodel id) so pool pressure is attributable: when G sub-models
share one pool, ``utilization_by_owner`` says which circuit is squeezing it.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional


class PagePoolOOM(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class PagePool:
    """Fixed-size page pool with a free list and per-sequence page tables."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list, low ids first off the stack (page 0 never enters)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._owners: Dict[int, Hashable] = {}      # seq_id -> owner tag

    # -- accounting ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (everything except the reserved null page)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.capacity - len(self._free)

    def utilization(self) -> float:
        """Fraction of allocatable pages currently owned by sequences."""
        return self.used_pages / self.capacity

    def utilization_by_owner(self) -> Dict[Hashable, float]:
        """Per-owner fraction of allocatable pages (owners are the tags
        passed at ``alloc``/``alloc_pages`` time; untagged sequences pool
        under ``None``).  Values sum to ``utilization()``."""
        out: Dict[Hashable, float] = {}
        for seq_id, table in self._tables.items():
            owner = self._owners.get(seq_id)
            out[owner] = out.get(owner, 0.0) + len(table) / self.capacity
        return out

    def pages_for(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)       # ceil div

    def can_alloc(self, n_pages: int) -> bool:
        return len(self._free) >= n_pages

    # -- allocation ---------------------------------------------------------
    def alloc(self, seq_id: int, num_tokens: int,
              owner: Optional[Hashable] = None) -> List[int]:
        """Register ``seq_id`` and allocate pages for its first
        ``num_tokens`` tokens.  Returns the page table (a live view)."""
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id} already allocated")
        self._tables[seq_id] = []
        self._owners[seq_id] = owner
        try:
            self.ensure(seq_id, num_tokens)
        except PagePoolOOM:
            del self._tables[seq_id]
            del self._owners[seq_id]
            raise
        return self._tables[seq_id]

    def alloc_pages(self, seq_id: int, n_pages: int,
                    owner: Optional[Hashable] = None) -> List[int]:
        """Register ``seq_id`` and allocate exactly ``n_pages`` pages — the
        pages-denominated sibling of ``alloc`` (admission policies think in
        pages; round-tripping pages -> tokens -> pages invites off-by-ones).
        Returns the page table (a live view)."""
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id} already allocated")
        if n_pages > len(self._free):
            raise PagePoolOOM(
                f"page pool exhausted: seq {seq_id} needs {n_pages} page(s) "
                f"at admission, {len(self._free)} free of "
                f"{self.num_pages - 1} ({self.utilization():.0%} utilized)")
        self._tables[seq_id] = [self._free.pop() for _ in range(n_pages)]
        self._owners[seq_id] = owner
        return self._tables[seq_id]

    def ensure(self, seq_id: int, num_tokens: int) -> List[int]:
        """Grow ``seq_id``'s table to cover ``num_tokens`` tokens, pulling
        pages from the free list on demand.  Raises PagePoolOOM (leaving the
        existing allocation intact) when the pool is exhausted."""
        table = self._tables[seq_id]
        need = self.pages_for(num_tokens) - len(table)
        if need > len(self._free):
            raise PagePoolOOM(
                f"page pool exhausted: seq {seq_id} needs {need} more "
                f"page(s), {len(self._free)} free of {self.num_pages - 1} "
                f"({self.utilization():.0%} utilized)")
        for _ in range(max(0, need)):
            table.append(self._free.pop())
        return table

    def free_seq(self, seq_id: int) -> int:
        """Return all of ``seq_id``'s pages to the free list."""
        table = self._tables.pop(seq_id)
        self._owners.pop(seq_id, None)
        self._free.extend(reversed(table))
        return len(table)

    def table(self, seq_id: int) -> List[int]:
        return list(self._tables[seq_id])

    @property
    def num_seqs(self) -> int:
        return len(self._tables)

    # -- invariants (exercised by tests) ------------------------------------
    def check_invariants(self) -> None:
        owned = [p for t in self._tables.values() for p in t]
        assert 0 not in owned, "null page allocated to a sequence"
        assert 0 not in self._free, "null page on the free list"
        assert len(set(owned)) == len(owned), "page owned by two sequences"
        overlap = set(owned) & set(self._free)
        assert not overlap, f"pages both free and owned: {overlap}"
        assert len(owned) + len(self._free) == self.num_pages - 1, \
            "pages leaked or duplicated"
        assert set(self._owners) == set(self._tables), \
            "owner registry out of sync with page tables"
