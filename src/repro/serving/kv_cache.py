"""Host-side paged KV-cache bookkeeping: ref-counted copy-on-write page
pool + page tables + an automatic prefix cache.

The device-side KV pools (``transformer.init_paged_cache``) are plain arrays
[num_pages, page_size, KH, D]; this module decides *which* page ids a
sequence owns.  Page ids are layer-agnostic — one allocation covers every
layer's pool, so the free list is a single flat structure regardless of
depth.  Page 0 is reserved as the null page: empty decode slots point their
block-table rows at it and their garbage writes land there harmlessly.

Sharing model (vLLM-style, adapted to Horn's ensembles):

  * every page carries a **refcount** = number of live sequence tables that
    map it.  ``fork``/``adopt`` map an existing page into another table
    (refcount + 1) instead of copying; ``free_seq`` decrements.
  * **copy-on-write**: before a sequence writes K/V into a page it shares
    (refcount > 1, or a page the prefix cache still indexes), the engine
    calls ``prepare_write`` — the pool swaps in a fresh page and returns
    (src, dst) pairs for a device-side page copy.  The last writer left
    holding a page (refcount 1, unindexed) writes in place.
  * **prefix cache**: full pages are content-addressed by a rolling hash
    chained over their token block (``chain_hashes``); a ``PrefixCache``
    maps hash -> page and keeps an LRU of *evictable* pages — published
    pages whose refcount has dropped to zero.  Such pages hold their bytes
    until allocation pressure reclaims them, so an identical prompt prefix
    admitted later maps the same pages and skips its prefill
    (``match_prefix``).  Hashes are namespaced: K/V bytes depend on which
    circuit encoded the tokens, so a dense-parent page never answers a
    lookup for a masked sub-model's prefix (and vice versa).

Allocations carry an optional *owner* tag (the serving engine passes the
request's submodel id) so pool pressure is attributable: when G sub-models
share one pool, ``utilization_by_owner`` says which circuit is squeezing
it.  A page shared by several owners is attributed once, to the owner of
the earliest-registered sequence mapping it, so per-owner page counts sum
exactly to ``used_pages``.

Under the scheduler's ``reserve`` policy an ensemble member's tail pages
are promised at admission but only position-mapped when the member forks
off the shared prompt prefix; ``deferred`` credits account for that promise
so intervening admissions cannot steal the reserved pages.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.paged_attention.kernel import NULL_PAGE


class PagePoolOOM(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list
    (plus whatever the prefix cache can evict)."""


def kv_page_bytes(page_size: int, kv_heads: int, head_dim: int,
                  dtype: str = "bfloat16") -> int:
    """HBM bytes one K+V page pair costs per layer, including the int8
    scale sidecar (two f32 scalars per (page, kv-head): one for K, one
    for V).  The int8/bf16 ratio is the engine's effective capacity gain
    at equal HBM — ~2x for realistic page_size * head_dim (the 8-byte
    scale overhead per head is amortized over page_size * head_dim
    elements)."""
    elems = page_size * kv_heads * head_dim
    itemsize = {"int8": 1, "bfloat16": 2, "float16": 2, "float32": 4}
    per_pool = elems * itemsize[str(dtype)]
    sidecar = kv_heads * 4 if str(dtype) == "int8" else 0
    return 2 * (per_pool + sidecar)


def chain_hashes(namespace: bytes, tokens, page_size: int) -> List[bytes]:
    """Content ids for every FULL page of ``tokens``: hash i covers token
    block [i * page_size, (i+1) * page_size) *chained on the previous
    block's hash*, so a page's id pins the entire prefix behind it — two
    streams share hash i only if they agree on every token before
    (i+1) * page_size.  ``namespace`` seeds the chain: K/V bytes are a
    function of (tokens, encoder), so pages encoded by different circuits
    must never answer each other's lookups."""
    toks = np.asarray(tokens, np.int32)
    out: List[bytes] = []
    prev = hashlib.blake2b(namespace, digest_size=16).digest()
    for i in range(len(toks) // page_size):
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(toks[i * page_size:(i + 1) * page_size].tobytes())
        prev = h.digest()
        out.append(prev)
    return out


class PrefixCache:
    """Content index + LRU over immutable full pages.

    ``index`` maps content hash -> page id for every *published* page —
    live-referenced or not — so concurrent requests share pages that are
    still being decoded against.  Only pages whose refcount has dropped to
    zero sit in the ``lru`` (eviction order: least recently freed first);
    they keep their bytes until ``pop_evictable`` reclaims one for a fresh
    allocation."""

    def __init__(self) -> None:
        self.index: Dict[bytes, int] = {}        # hash -> page id
        self.lru: "OrderedDict[int, bytes]" = OrderedDict()  # evictable
        self.neg: set = set()   # chain-head hashes known cold (see match)
        self.hits = 0           # pages adopted from the index
        self.misses = 0         # adoptions whose lookup fell short
        self.neg_hits = 0       # walks short-circuited by the negative cache
        self.evictions = 0      # cached pages reclaimed for allocation
        self.inserts = 0

    @property
    def evictable(self) -> int:
        return len(self.lru)

    def stats(self) -> Dict[str, int]:
        """Lifetime counters + current index occupancy, for the telemetry
        snapshot (the engine's windowed eviction delta stays on the
        engine: these never reset)."""
        return {
            "indexed_pages": len(self.index),
            "evictable_pages": self.evictable,
            "hits": self.hits,
            "misses": self.misses,
            "neg_hits": self.neg_hits,
            "evictions": self.evictions,
            "inserts": self.inserts,
        }

    def match(self, hashes: Sequence[bytes], *,
              peek: bool = False) -> List[int]:
        """Longest indexed prefix of ``hashes`` -> page ids.  Chained
        hashes make prefix matching a linear walk: the first miss ends it.

        ``peek`` marks a feasibility probe (a blocked FCFS head replanning
        every tick): it must not distort the hit/miss statistics — those
        are committed once, on actual adoption, via ``commit_match``.
        Either way the walk consults (and feeds) the *negative cache*: a
        chain-head hash that missed is remembered as cold, so a blocked or
        cold-prompt request stops re-probing every tick; ``publish``
        invalidates the negative set (new pages may warm any prefix)."""
        if hashes and hashes[0] in self.neg:
            self.neg_hits += 1
            if not peek:               # a committed cold lookup is a miss
                self.commit_match(0, True)
            return []
        pages: List[int] = []
        for h in hashes:
            page = self.index.get(h)
            if page is None:
                break
            pages.append(page)
        if hashes and not pages:
            self.neg.add(hashes[0])      # known-cold until the next publish
        if not peek:
            self.commit_match(len(pages), len(pages) < len(hashes))
        return pages

    def commit_match(self, n_hit: int, missed: bool) -> None:
        """Fold one *adopted* lookup into the hit/miss statistics (peek
        probes are free — only admissions that actually map pages count)."""
        self.hits += n_hit
        if missed:
            self.misses += 1

    def publish(self, h: bytes, page: int) -> bool:
        """Index ``page`` under ``h``; no-op (False) when the hash is
        already indexed (a concurrent identical prefill got there first —
        the duplicate page simply stays anonymous and frees normally)."""
        if h in self.index:
            return False
        self.index[h] = page
        self.inserts += 1
        # a fresh page can warm any prefix whose walk previously went cold
        # at its chain head — the negative cache is only valid between
        # publishes, so drop it wholesale
        self.neg.clear()
        return True

    def release(self, page: int, h: bytes) -> None:
        """Page's refcount hit zero: hold it, most-recently-used."""
        self.lru[page] = h
        self.lru.move_to_end(page)

    def reacquire(self, page: int) -> None:
        """Page picked up by a live sequence again: no longer evictable."""
        self.lru.pop(page, None)

    def pop_evictable(self, pinned: frozenset = frozenset()) -> Optional[int]:
        """Reclaim the least-recently-freed evictable page (skipping
        ``pinned`` — pages an in-flight admission is about to adopt) and
        drop its index entry.  None when nothing can go."""
        for page, h in self.lru.items():
            if page not in pinned:
                del self.lru[page]
                del self.index[h]
                self.evictions += 1
                return page
        return None

    def forget(self, page: int, h: bytes) -> None:
        """Drop ``page`` from the index without reclaiming it (COW safety
        path: the bytes are about to be overwritten in place)."""
        self.lru.pop(page, None)
        if self.index.get(h) == page:
            del self.index[h]


class PagePool:
    """Fixed-size page pool: free list, per-sequence page tables, page
    refcounts, and (optionally) a prefix cache of retired full pages."""

    def __init__(self, num_pages: int, page_size: int, *,
                 prefix_cache: bool = False):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list, low ids first off the stack (page 0 never enters)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._tables: Dict[int, List[int]] = {}
        self._owners: Dict[int, Hashable] = {}      # seq_id -> owner tag
        self._ref: Dict[int, int] = {}              # page -> live table refs
        self._hash_of: Dict[int, bytes] = {}        # page -> published hash
        self._deferred: Dict[int, int] = {}         # seq_id -> promised pages
        self._version: Dict[int, int] = {}          # seq_id -> table mutations
        self.cache: Optional[PrefixCache] = PrefixCache() if prefix_cache \
            else None
        self.cow_copies = 0

    # -- accounting ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable pages (everything except the reserved null page)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Retired pages the prefix cache is holding (reclaimable)."""
        return self.cache.evictable if self.cache is not None else 0

    @property
    def used_pages(self) -> int:
        """Distinct pages mapped by at least one live sequence."""
        return self.capacity - len(self._free) - self.cached_pages

    @property
    def deferred_pages(self) -> int:
        """Pages promised to admitted sequences but not yet mapped."""
        return sum(self._deferred.values())

    def utilization(self) -> float:
        """Fraction of allocatable pages currently mapped by sequences
        (cache-held pages are reclaimable and do not count)."""
        return self.used_pages / self.capacity

    def pages_by_owner(self) -> Dict[Hashable, int]:
        """Distinct mapped pages per owner tag.  A page shared by several
        sequences counts once, for the owner of the earliest-registered
        sequence mapping it (deterministic: insertion order of ``alloc``),
        so values sum exactly to ``used_pages``."""
        out: Dict[Hashable, int] = {}
        seen: set = set()
        for seq_id, table in self._tables.items():   # insertion-ordered
            owner = self._owners.get(seq_id)
            n = 0
            for p in table:
                if p not in seen:
                    seen.add(p)
                    n += 1
            if n or owner not in out:
                out[owner] = out.get(owner, 0) + n
        return out

    def utilization_by_owner(self) -> Dict[Hashable, float]:
        """Per-owner fraction of allocatable pages: integer page counts
        per owner (``pages_by_owner``) divided once by ``capacity``."""
        return {o: n / self.capacity for o, n in self.pages_by_owner().items()}

    def stats(self) -> Dict[str, object]:
        """Point-in-time occupancy snapshot for the telemetry layer (the
        `pool` block of ``Engine.metrics()`` and the Perfetto counter
        track)."""
        return {
            "capacity": self.capacity,
            "used_pages": self.used_pages,
            "free_pages": self.free_pages,
            "cached_pages": self.cached_pages,
            "deferred_pages": self.deferred_pages,
            "utilization": self.utilization(),
            "cow_copies": self.cow_copies,
            "live_seqs": len(self._tables),
            "pages_by_owner": dict(self.pages_by_owner()),
        }

    def live_table_pages(self) -> int:
        """Distinct pages actually referenced by live sequence tables —
        the ground-truth counterpart of the ``used_pages`` accounting
        identity (capacity - free - cached).  COW/fork shares count
        once.  The two disagree only when pages left the free list but
        no live table can reach them (deferred credits keep their pages
        ON the free list until redeemed, so promises don't skew this):
        the pool-leak watchdog's signal.  Walks every table, so callers
        sample it every N ticks rather than every tick."""
        seen: set = set()
        for table in self._tables.values():
            seen.update(table)
        return len(seen)

    def pages_for(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)       # ceil div

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def table_version(self, seq_id: int) -> int:
        """Bumped on every mutation of ``seq_id``'s table (page appended,
        adopted, or COW-swapped) — cheap dirtiness key for block-table
        row sync."""
        return self._version[self._known(seq_id)]

    # -- internal plumbing --------------------------------------------------
    def _known(self, seq_id: int) -> int:
        if seq_id not in self._tables:
            raise ValueError(
                f"sequence {seq_id} is not allocated in this pool (never "
                f"registered, or already freed — double free?); live "
                f"sequences: {sorted(self._tables)[:8]}"
                f"{'...' if len(self._tables) > 8 else ''}")
        return seq_id

    def allocatable(self, *, pinned: frozenset = frozenset()) -> int:
        """Pages a NEW allocation could draw on right now: the free list
        plus evictable cached pages (minus any an in-flight admission has
        pinned), minus pages already promised to other sequences."""
        evictable = 0
        if self.cache is not None:       # O(|pinned|), not O(cached pages)
            evictable = self.cache.evictable \
                - sum(1 for p in pinned if p in self.cache.lru)
        return len(self._free) + evictable - self.deferred_pages

    def can_alloc(self, n_pages: int, *,
                  pinned: frozenset = frozenset()) -> bool:
        return self.allocatable(pinned=pinned) >= n_pages

    def _take(self, seq_id: int, pinned: frozenset = frozenset()) -> int:
        """One physical page off the free list (evicting from the prefix
        cache when the list is dry), honoring deferred credits: a sequence
        with promised pages consumes its own promise first; anyone else
        must leave the promised pages untouched."""
        credit = self._deferred.get(seq_id, 0)
        if credit:
            self._deferred[seq_id] = credit - 1
        elif self.allocatable(pinned=pinned) < 1:
            raise PagePoolOOM(
                f"page pool exhausted: seq {seq_id} needs 1 more page, "
                f"{len(self._free)} free + {self.cached_pages} cached of "
                f"{self.capacity} with {self.deferred_pages} promised "
                f"({self.utilization():.0%} utilized)")
        if self._free:
            return self._free.pop()
        page = self.cache.pop_evictable(pinned) if self.cache else None
        if page is None:                 # credit promised more than exists
            raise PagePoolOOM(
                f"page pool exhausted: seq {seq_id} holds an unredeemable "
                f"page promise ({len(self._free)} free, "
                f"{self.cached_pages} cached)")
        self._hash_of.pop(page, None)
        return page

    def _retire(self, page: int) -> None:
        """Page's last reference is gone: park it in the prefix cache when
        it is published (its bytes may serve a future prefix match), else
        return it to the free list."""
        h = self._hash_of.get(page)
        if self.cache is not None and h is not None:
            self.cache.release(page, h)
        else:
            self._hash_of.pop(page, None)
            self._free.append(page)

    def _map(self, seq_id: int, page: int) -> None:
        self._tables[seq_id].append(page)
        self._ref[page] = self._ref.get(page, 0) + 1
        self._version[seq_id] += 1

    # -- allocation ---------------------------------------------------------
    def alloc(self, seq_id: int, num_tokens: int,
              owner: Optional[Hashable] = None) -> List[int]:
        """Register ``seq_id`` and allocate pages for its first
        ``num_tokens`` tokens.  Returns the page table (a live view)."""
        self.alloc_pages(seq_id, self.pages_for(num_tokens), owner=owner)
        return self._tables[seq_id]

    def alloc_pages(self, seq_id: int, n_pages: int,
                    owner: Optional[Hashable] = None, *,
                    cached: Sequence[int] = (), deferred: int = 0
                    ) -> List[int]:
        """Register ``seq_id``: adopt ``cached`` pages (a ``match_prefix``
        result — mapped first, in order, refcount + 1 each), then allocate
        ``n_pages`` fresh pages, then promise ``deferred`` more for later
        (``reserve``-policy ensemble tails).  Atomic: on OOM nothing is
        registered.  Returns the page table (a live view)."""
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id} already allocated")
        pinned = frozenset(cached)
        if self.allocatable(pinned=pinned) < n_pages + deferred:
            raise PagePoolOOM(
                f"page pool exhausted: seq {seq_id} needs {n_pages} page(s) "
                f"+ {deferred} promised at admission, {len(self._free)} free "
                f"+ {self.cached_pages} cached of {self.capacity} with "
                f"{self.deferred_pages} already promised "
                f"({self.utilization():.0%} utilized)")
        self._tables[seq_id] = []
        self._owners[seq_id] = owner
        self._version[seq_id] = 0
        for page in cached:
            if self._ref.get(page, 0) == 0 and self.cache is not None:
                self.cache.reacquire(page)
            self._map(seq_id, page)
        for _ in range(n_pages):
            self._map(seq_id, self._take(seq_id, pinned))
        if deferred:
            self._deferred[seq_id] = deferred
        return self._tables[seq_id]

    def ensure(self, seq_id: int, num_tokens: int) -> List[int]:
        """Grow ``seq_id``'s table to cover ``num_tokens`` tokens, pulling
        pages from the free list (or the prefix cache's LRU) on demand.
        Raises PagePoolOOM (leaving the existing allocation intact) when
        the pool is exhausted."""
        table = self._tables[self._known(seq_id)]
        need = self.pages_for(num_tokens) - len(table)
        credit = self._deferred.get(seq_id, 0)
        if need - credit > self.allocatable():
            raise PagePoolOOM(
                f"page pool exhausted: seq {seq_id} needs {need} more "
                f"page(s), {len(self._free)} free + {self.cached_pages} "
                f"cached of {self.capacity} with {self.deferred_pages} "
                f"promised ({self.utilization():.0%} utilized)")
        for _ in range(max(0, need)):
            self._map(seq_id, self._take(seq_id))
        return table

    def fork(self, src_seq: int, dst_seq: int,
             owner: Optional[Hashable] = None, *,
             num_pages: Optional[int] = None) -> List[int]:
        """Map the first ``num_pages`` pages (default: all) of ``src_seq``
        into a fresh table for ``dst_seq`` — refcount + 1 per page, no
        copy.  Writes into shared pages go through ``prepare_write``."""
        src = self._tables[self._known(src_seq)]
        shared = src[:len(src) if num_pages is None else num_pages]
        return self.alloc_pages(dst_seq, 0, owner=owner, cached=shared)

    def adopt_prefix(self, seq_id: int, pages: Sequence[int]) -> None:
        """Prepend already-materialized shared pages to ``seq_id``'s table
        (refcount + 1 each) — the ensemble-member fork for a sequence that
        was registered page-less at admission.  The table must still be
        empty: adopted pages cover positions [0, len * page_size)."""
        table = self._tables[self._known(seq_id)]
        if table:
            raise ValueError(
                f"sequence {seq_id} already maps {len(table)} page(s); "
                f"prefix adoption must precede its own allocations")
        for page in pages:
            if self._ref.get(page, 0) == 0 and self.cache is not None:
                self.cache.reacquire(page)
            self._map(seq_id, page)

    # -- copy-on-write ------------------------------------------------------
    def prepare_write(self, seq_id: int, first_token: int,
                      last_token: int) -> List[Tuple[int, int]]:
        """Make the pages covering token positions [first_token,
        last_token) privately writable by ``seq_id``: any page shared with
        another table (refcount > 1) is COW-swapped for a fresh page and
        the (src, dst) pair returned so the caller can issue the device
        copy; a page the prefix cache still indexes (refcount 1) is simply
        un-published — its bytes are about to change in place.  Raises
        PagePoolOOM when no fresh page can back a needed copy."""
        table = self._tables[self._known(seq_id)]
        pairs: List[Tuple[int, int]] = []
        lo = first_token // self.page_size
        hi = self.pages_for(last_token)
        for i in range(lo, min(hi, len(table))):
            page = table[i]
            if self._ref.get(page, 0) > 1:
                fresh = self._take(seq_id)
                self._ref[page] -= 1
                table[i] = fresh
                self._ref[fresh] = self._ref.get(fresh, 0) + 1
                self._version[seq_id] += 1
                pairs.append((page, fresh))
                self.cow_copies += 1
            elif self.cache is not None and page in self._hash_of:
                self.cache.forget(page, self._hash_of.pop(page))
        return pairs

    # -- prefix cache -------------------------------------------------------
    def match_pages(self, hashes: Sequence[bytes], *,
                    peek: bool = False) -> List[int]:
        """Longest content-indexed prefix of ``hashes`` -> page ids (empty
        when the pool runs without a prefix cache).  ``peek`` marks a
        feasibility probe that must not count toward hit/miss stats."""
        if self.cache is None:
            return []
        return self.cache.match(hashes, peek=peek)

    def commit_match(self, n_hit: int, missed: bool) -> None:
        """Commit one adopted lookup's hit/miss statistics (the peek
        probes that sized it were free)."""
        if self.cache is not None:
            self.cache.commit_match(n_hit, missed)

    def match_prefix(self, namespace: bytes, tokens,
                     max_tokens: Optional[int] = None
                     ) -> Tuple[List[int], int]:
        """Longest cached page-prefix of ``tokens`` under ``namespace``:
        (page ids, tokens they cover).  ``max_tokens`` caps the match (a
        fresh request must recompute at least its last prompt token — the
        chunk that completes prefill yields the first sampled token)."""
        if self.cache is None:
            return [], 0
        toks = np.asarray(tokens, np.int32)
        n = len(toks) if max_tokens is None else min(len(toks), max_tokens)
        hashes = chain_hashes(namespace, toks[:n - n % self.page_size],
                              self.page_size)
        pages = self.cache.match(hashes)
        return pages, len(pages) * self.page_size

    def publish_prefix(self, seq_id: int, hashes: Sequence[bytes],
                       num_pages: int) -> int:
        """Content-index the first ``num_pages`` pages of ``seq_id``'s
        table under ``hashes`` (their chained content ids) once their K/V
        is fully materialized.  Already-published pages (adopted via a
        prefix match) and hash collisions with a concurrent identical
        prefill are skipped.  Returns pages newly indexed."""
        if self.cache is None:
            return 0
        table = self._tables[self._known(seq_id)]
        new = 0
        for i in range(min(num_pages, len(hashes), len(table))):
            page = table[i]
            if page in self._hash_of:
                continue
            if self.cache.publish(hashes[i], page):
                self._hash_of[page] = hashes[i]
                new += 1
        return new

    def truncate_seq(self, seq_id: int, num_tokens: int, *,
                     recredit: bool = False) -> int:
        """Drop ``seq_id``'s page references beyond the pages covering its
        first ``num_tokens`` tokens — the speculative-decode rollback: a
        rejected draft tail is a ref-release, not a copy.  Shared pages
        survive under their other holders; exclusive pages return to the
        free list (or the prefix cache when published).  ``recredit`` turns
        each physically reclaimed page into a deferred credit for
        ``seq_id`` (reserve-policy engines: the reservation made at
        admission must survive the rollback, or a later re-grow could OOM
        against pages another admission took in between).  Returns pages
        released."""
        table = self._tables[self._known(seq_id)]
        keep = self.pages_for(num_tokens)
        dropped = 0
        while len(table) > keep:
            page = table.pop()
            self._ref[page] -= 1
            if self._ref[page] == 0:
                del self._ref[page]
                self._retire(page)
                if recredit:
                    self._deferred[seq_id] = \
                        self._deferred.get(seq_id, 0) + 1
            dropped += 1
        if dropped:
            self._version[seq_id] += 1
        return dropped

    # -- release ------------------------------------------------------------
    def free_seq(self, seq_id: int) -> int:
        """Drop all of ``seq_id``'s page references: each page's refcount
        falls by one, and pages nobody maps anymore return to the free
        list — or, when published in the prefix cache, are held there
        (evictable) so their bytes can serve future prefix matches.
        Raises a descriptive ValueError on an unknown or already-freed
        ``seq_id`` (an overlapping preempt/finish double free must surface
        loudly, not as silent refcount corruption)."""
        table = self._tables.pop(self._known(seq_id))
        self._owners.pop(seq_id, None)
        self._deferred.pop(seq_id, None)
        self._version.pop(seq_id, None)
        for page in reversed(table):
            self._ref[page] -= 1
            if self._ref[page] == 0:
                del self._ref[page]
                self._retire(page)
        return len(table)

    def table(self, seq_id: int) -> List[int]:
        return list(self._tables[self._known(seq_id)])

    @property
    def num_seqs(self) -> int:
        return len(self._tables)

    # -- invariants (exercised by tests) ------------------------------------
    def check_invariants(self) -> None:
        refs: Dict[int, int] = {}
        for seq_id, t in self._tables.items():
            assert len(set(t)) == len(t), \
                f"seq {seq_id} maps a page twice: {t}"
            for p in t:
                refs[p] = refs.get(p, 0) + 1
        assert NULL_PAGE not in refs, "null page mapped by a sequence"
        assert NULL_PAGE not in self._free, "null page on the free list"
        assert refs == self._ref, \
            f"refcounts out of sync with tables: {self._ref} != {refs}"
        overlap = set(refs) & set(self._free)
        assert not overlap, f"pages both free and mapped: {overlap}"
        cached = set()
        if self.cache is not None:
            cached = set(self.cache.lru)
            assert not cached & set(refs), \
                "cache-held (evictable) page still mapped by a live seq"
            assert not cached & set(self._free), \
                "cache-held page also on the free list"
            for h, p in self.cache.index.items():
                assert self._hash_of.get(p) == h, \
                    f"index entry {p} disagrees with page hash registry"
            for p, h in self.cache.lru.items():
                assert self.cache.index.get(h) == p, \
                    f"evictable page {p} not content-indexed"
            assert not self.cache.neg & set(self.cache.index), \
                "negative-cache entry for an indexed chain head"
        for p in self._hash_of:
            assert p in refs or p in cached, \
                f"published page {p} neither mapped nor cache-held"
        assert len(refs) + len(self._free) + len(cached) \
            == self.num_pages - 1, "pages leaked or duplicated"
        assert set(self._owners) == set(self._tables), \
            "owner registry out of sync with page tables"
        assert set(self._version) == set(self._tables), \
            "version registry out of sync with page tables"
        assert all(v >= 0 for v in self._deferred.values())
        assert set(self._deferred) <= set(self._tables), \
            "deferred credit for a dead sequence"
        assert self.deferred_pages <= len(self._free) + len(cached), \
            "more pages promised than physically reclaimable"
        by_owner = self.pages_by_owner()
        assert sum(by_owner.values()) == self.used_pages, \
            f"per-owner page counts {by_owner} do not sum to used_pages"
