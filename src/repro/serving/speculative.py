"""Speculative decoding: a materialized Horn small circuit drafts, the
parent verifies.

Per engine tick, every speculating decode slot runs the draft circuit
autoregressively for up to K tokens — ONE jitted draft call (catch-up
chunk + an on-device ``lax.scan`` of single-token steps), batched across
slots — and the parent then verifies all K+1 positions inside the same
single token-budget call every other slot shares (the chunk-append paged
path: a verify chunk is just a K+1-token chunk whose window of logits is
scored against the drafts).  K sequential parent ticks collapse into one.

The draft's KV lives in a *private* page pool + paged cache, NOT the
parent's: the circuit's K/V bytes differ from the parent's for the same
tokens (different FFNs feed the residual stream), so pages can never be
shared across the two — and a draft page must never answer a parent
prefix-cache lookup.  The pool is deliberately sized so it can never OOM
(``num_slots`` sequences of at most ``max_model_len + K`` tokens): draft
state is a pure function of a request's committed stream, is rebuilt by
the catch-up chunk after preemption, and therefore needs none of the
parent pool's preemption/COW machinery.  That is also why a dense
per-slot scratch cache was rejected only narrowly: paging reuses the
existing chunk kernel and per-slot depths for free, at identical memory.

Rollback is a ref-release: when the parent rejects a draft tail, the
runner's ``commit`` (and the engine, for the parent pages) truncate the
page tables back to the accepted prefix — stale K/V beyond it is
overwritten by the next write at those positions and is never read
(attention masks beyond each slot's valid length)."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HornConfig, RunConfig, ShapeConfig
from repro.core import steps as S
from repro.models import transformer as T
from repro.serving.block_table import (BlockTableMirror, marshal_i32,
                                       pow2_bucket)
from repro.serving.kv_cache import PagePool
from repro.serving.model_bank import DraftModel
from repro.serving.scheduler import Request


class DraftRunner:
    """Host-side orchestration of the draft circuit's speculative state:
    one private page pool + paged cache, a per-request draft position
    (committed tokens whose K/V the draft has written), and one jitted
    draft step per draft length in use."""

    def __init__(self, draft: DraftModel, ecfg, mesh=None):
        self.draft = draft
        self.ecfg = ecfg
        B = ecfg.num_slots
        self.k_max = ecfg.speculate_k
        psize = ecfg.page_size
        # worst case per slot: a full context plus the drafted tail
        max_tokens = ecfg.max_model_len + self.k_max
        self.max_pages_per_seq = -(-max_tokens // psize)
        self.pool = PagePool(B * self.max_pages_per_seq + 1, psize)
        self._run = RunConfig(
            model=draft.cfg,
            shape=ShapeConfig("serve", "decode", ecfg.max_model_len, B),
            horn=HornConfig(enabled=False), compute_dtype=ecfg.compute_dtype)
        self._mesh = mesh
        self.cache = T.init_paged_cache(draft.cfg, self.pool.num_pages,
                                        psize, dtype=jnp.dtype(ecfg.kv_dtype))
        self._steps: Dict[int, object] = {}      # draft length -> jitted step
        self._pos: Dict[int, int] = {}           # req id -> draft tokens in KV
        self._pending: Dict[int, Tuple[int, int]] = {}  # req id -> (n, k)
        self._bt = BlockTableMirror(B, self.max_pages_per_seq)
        self.draft_calls = 0

    def _step_for(self, k: int):
        if k not in self._steps:
            self._steps[k] = S.make_draft_spec_step(
                self._run, self._mesh, num_pages=self.pool.num_pages,
                page_size=self.ecfg.page_size, k=k,
                temperature=self.ecfg.temperature)
        return self._steps[k]

    def _catch_up_chunk(self, req: Request) -> np.ndarray:
        """The committed tokens the draft has not written K/V for:
        stream[pos, context_len) of prompt + out_tokens, sliced without
        rebuilding the whole stream (steady-state decode needs 1-2 tokens
        off the out_tokens tail, not an O(context) concat per tick)."""
        lo, plen = self._pos[req.id], req.prompt_len
        tail = np.asarray(req.out_tokens[max(0, lo - plen):], np.int32)
        if lo >= plen:
            return tail
        return np.concatenate([req.prompt[lo:], tail]) if len(tail) \
            else req.prompt[lo:]

    # -- per-tick API --------------------------------------------------------
    def propose(self, units: List[Tuple[int, Request]], k: int, root_key
                ) -> Tuple[np.ndarray, jnp.ndarray]:
        """Draft ``k`` tokens for every (slot, request) in ``units`` in one
        jitted call.  Returns (drafts [B, k] host int32, draft_probs
        [B, k, Vq] device f32 — the rejection sampler's q, a dummy width-1
        array under greedy).  Rows for slots not in ``units`` are garbage
        the verifier masks out (draft_lens == 0)."""
        B = self.ecfg.num_slots
        planned: Dict[int, Tuple[Request, np.ndarray]] = {}
        width = 1
        for slot, req in units:
            if req.id not in self._pos:
                self.pool.alloc_pages(req.id, 0, owner="draft")
                self._pos[req.id] = 0
            # K/V for d_k is written by the NEXT catch-up, like the
            # engine's pending token — hence context_len + k - 1
            self.pool.ensure(req.id, req.context_len + k - 1)
            chunk = self._catch_up_chunk(req)
            planned[slot] = (req, chunk)
            width = max(width, len(chunk))
        C = pow2_bucket(width)
        tokens = np.zeros((B, C), np.int32)
        starts = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        req_ids = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        for slot, (req, chunk) in planned.items():
            tokens[slot, :len(chunk)] = chunk
            starts[slot] = self._pos[req.id]
            lens[slot] = len(chunk)
            req_ids[slot] = req.id
            steps[slot] = len(req.out_tokens)
        # Only THIS tick's drafters are active: a slot not drafting is
        # deliberately synced to the null page, because the in-call scan
        # feeds every slot a token per step and an idle slot's garbage
        # writes must land on page 0, never in a live draft table.  The
        # state key folds in admit_seq like the engine's: table versions
        # reset on free/realloc, so (id, version) alone could repeat
        # across a preempt/re-admit cycle and keep a stale row.
        self._bt.sync(self.pool, {s: r for s, (r, _) in planned.items()},
                      lambda r: (r.id, r.admit_seq,
                                 self.pool.table_version(r.id)))
        (d_tokens, d_starts, d_lens, d_req_ids, d_steps) = marshal_i32(
            tokens, starts, lens, req_ids, steps)
        drafts, probs, self.cache = self._step_for(k)(
            self.draft.params, self.cache, d_tokens, d_starts, d_lens,
            self._bt.dev, d_req_ids, d_steps, root_key)
        self.draft_calls += 1
        for slot, (req, _) in planned.items():
            self._pending[req.id] = (req.context_len, k)
            self._pos[req.id] = req.context_len + k - 1
        # deliberate: the engine edits drafted tokens into the verify
        # chunks on the host, so the proposal is pulled here
        return np.asarray(drafts), probs          # hornlint: sync-ok

    def commit(self, req: Request, accepted: int) -> None:
        """Verify verdict for ``req``'s last proposal: keep the accepted
        draft prefix's K/V, release the rejected tail's pages (ref-release;
        stale K/V inside the boundary page is overwritten by the next
        catch-up write at those positions)."""
        n, k = self._pending.pop(req.id)
        self._pos[req.id] = min(n + accepted, n + k - 1)
        self.pool.truncate_seq(req.id, self._pos[req.id])

    def drop(self, req_id: int) -> None:
        """Forget a request entirely (finished, preempted, or aborted):
        draft state is reconstructible from the committed stream, so a
        preempted request simply pays one catch-up chunk on re-admission —
        and the never-OOM pool sizing needs at most ``num_slots`` live
        draft sequences."""
        if req_id in self._pos:
            self.pool.free_seq(req_id)
            del self._pos[req_id]
            self._pending.pop(req_id, None)

    def stats(self) -> dict:
        """Draft-side snapshot for the telemetry layer (acceptance
        accounting lives on the parent engine's counters)."""
        return {
            "draft_calls": self.draft_calls,
            "live_seqs": len(self._pos),
            "pool_utilization": self.pool.utilization(),
        }
