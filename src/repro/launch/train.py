"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 100 --batch 8 --seq 512 [--mesh-data 1 --mesh-model 1] \
        [--topology allreduce|local_sgd] [--checkpoint-dir ckpt/]

On the CPU container this runs the REAL production code path (pjit train step,
Horn parallel dropout, deterministic pipeline, async checkpoints, preemption
handling) on a 1x1 mesh with reduced configs — the same path the dry-run
proves at (2, 16, 16).  ``--arch horn-mnist`` runs the paper's MNIST
experiment through the neuron-centric engine instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.base import (HornConfig, RunConfig, ShapeConfig,
                                TopologyConfig, get_model_config, list_archs,
                                reduced)
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import steps as S
from repro.data.pipeline import SyntheticTokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_test_mesh
from repro.runtime.fault_tolerance import (NanGuard, PreemptionHandler,
                                           fault_tolerant_loop)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgdm"])
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (default: reduced)")
    ap.add_argument("--no-horn", action="store_true",
                    help="disable parallel dropout")
    ap.add_argument("--horn-groups", type=int, default=0)
    ap.add_argument("--topology", default="allreduce",
                    choices=["allreduce", "zero1", "local_sgd"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.arch == "horn-mnist":
        from repro.core.collective_trainer import train_mnist
        res = train_mnist(num_groups=args.horn_groups or 20,
                          batch_per_group=max(1, args.batch // 20),
                          num_steps=args.steps, lr=args.lr or 0.005,
                          eval_every=max(50, args.steps // 5),
                          seed=args.seed)
        print(json.dumps(res.row(), indent=1))
        return

    cfg = get_model_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    run = RunConfig(
        model=cfg, shape=shape,
        horn=HornConfig(enabled=not args.no_horn,
                        num_groups=args.horn_groups),
        topology=TopologyConfig(kind=args.topology),
        optimizer=args.optimizer, learning_rate=args.lr, seed=args.seed)
    mesh = make_test_mesh(args.mesh_data, args.mesh_model)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}  "
          f"arch: {cfg.name}  params: {cfg.param_count():,}")

    step_fn, shardings = S.make_train_step(run, mesh)
    state = jax.jit(lambda k: S.init_state(k, run),
                    out_shardings=shardings["state"])(jax.random.key(args.seed))

    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))

    def batch_at(step: int):
        b = pipe.batch_at(step)
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = np.zeros((args.batch, cfg.encoder_seq,
                                        cfg.d_model), np.float32)
        if cfg.num_patches:
            extra["patch_embeds"] = np.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), np.float32)
            b = {k: v[:, : args.seq - cfg.num_patches] for k, v in b.items()}
        return {**b, **extra}

    t0 = time.time()
    losses = []

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            dt = time.time() - t0
            tok = step * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"{tok:,.0f} tok/s")

    if args.checkpoint_dir:
        ck = Checkpointer(args.checkpoint_dir)
        if ck.latest_step() is not None:
            state, at = ck.restore(state, shardings=shardings["state"])
            print(f"resumed from step {at}")
        state, last, reason = fault_tolerant_loop(
            state=state, step_fn=step_fn, batch_at=batch_at,
            checkpointer=ck, num_steps=args.steps,
            checkpoint_every=args.checkpoint_every,
            state_shardings=shardings["state"], on_metrics=on_metrics)
        print(f"exit: {reason} at step {last}")
    else:
        for step in range(args.steps):
            state, metrics = step_fn(state, batch_at(step))
            on_metrics(step + 1, metrics)
    if losses:
        print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
              f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")


if __name__ == "__main__":
    main()
