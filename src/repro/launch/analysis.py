"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the methodology in EXPERIMENTS.md:

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

IMPORTANT: ``compiled.cost_analysis()`` visits each while-loop *body once*,
so everything inside lax.scan (i.e. the entire layer stack) is undercounted
by its trip count.  We therefore implement our own HLO-text cost model
(:class:`HloCost`): it parses computations, builds the call graph
(while bodies x trip count, fusions/calls x 1), and accumulates

  * matmul FLOPs from ``dot`` ops (2 * output_elems * contracted_dim),
  * an HBM-traffic proxy (operand + output bytes of top-level ops; fusion
    internals are free, matching real fusion behaviour),
  * collective wire bytes per op kind (simple = output bytes, matching the
    assignment formula; ring = (n-1)/n scaling, 2x for all-reduce).

The compiled module is the per-device SPMD program, so every figure is
per-chip; the roofline terms divide by per-chip peaks, which equals the
assignment's global/(chips * peak) formulation.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# --- TPU v5e hardware constants (assignment-specified) ----------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link (one link-equivalent per chip)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# `%x = bf16[2,128]{1,0} all-gather(...)` or tuple shapes
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"ragged-all-to-all)(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in a (possibly tuple) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_simple: int = 0              # sum of output bytes (assignment formula)
    bytes_ring: float = 0.0            # ring-model wire bytes
    count: int = 0
    by_kind: Dict[str, int] = field(default_factory=dict)
    by_kind_count: Dict[str, int] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# HLO-text cost model (trip-count aware)
# ---------------------------------------------------------------------------
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\{\s*$")
_OP_LINE_RE = re.compile(
    r"^\s+(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\(")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_INT_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

# Scope tags for attribution (jax.named_scope markers in model code).  Ops
# whose op_name contains a tag get attributed to it — used to quantify e.g.
# how much HBM traffic the Pallas flash-attention kernel would collapse.
SCOPE_TAGS = ("flash_attn", "ssd_chunk", "moe_ffn", "xent_chunk", "mlp_block")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs", "compare",
    "select", "and", "or", "xor", "clamp", "floor", "ceil", "sign",
    "exponential-minus-one", "log-plus-one", "cosine", "sine", "atan2",
}
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "reshape", "while", "call", "conditional", "fusion-marker",
    "partition-id", "replica-id", "custom-call-marker",
}


class _Comp:
    __slots__ = ("name", "ops", "defs", "flops", "bytes", "coll_simple",
                 "coll_ring", "coll_by_kind", "coll_count", "callees",
                 "tag_flops", "tag_bytes")

    def __init__(self, name):
        self.name = name
        self.ops = []           # (name, shape_str, opcode, line)
        self.defs = {}          # name -> shape_str
        self.flops = 0.0
        self.bytes = 0.0
        self.coll_simple = 0.0
        self.coll_ring = 0.0
        self.coll_by_kind = {}
        self.coll_count = 0
        self.callees = []       # (callee_name, multiplier, is_fusion)
        self.tag_flops = {}
        self.tag_bytes = {}


def _elems(shape_str: str) -> int:
    n = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        k = 1
        for d in dims.split(","):
            if d.strip():
                k *= int(d)
        n += k
    return n


def _dims_of(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


class HloCost:
    """Trip-count-aware cost model over post-optimization HLO text."""

    def __init__(self, hlo_text: str):
        self.comps: Dict[str, _Comp] = {}
        self.entry: Optional[str] = None
        self.cast_bytes_local: Dict[str, float] = {}
        self._parse(hlo_text)
        self._analyze_ops()
        self.mult, self.mem_mult = self._multipliers()

    @staticmethod
    def _is_pure_cast(comp, shape_str: str, opcode: str, line: str) -> bool:
        """convert, or a convert/copy/transpose-only fusion: one non-scalar
        operand with the same dims but different byte-width."""
        if opcode == "convert":
            return True
        if opcode != "fusion":
            return False
        if not any(k in line for k in ("convert", "copy", "transpose")):
            return False
        out_dims = sorted(_dims_of(shape_str))
        out_b = shape_bytes(shape_str)
        args = line.split("(", 1)[1] if "(" in line else ""
        big = [r for r in re.findall(r"%[\w\.\-]+", args)
               if r in comp.defs and shape_bytes(comp.defs[r]) > 1024]
        if len(big) != 1:
            return False
        od = sorted(_dims_of(comp.defs[big[0]]))
        return od == out_dims and shape_bytes(comp.defs[big[0]]) != out_b

    # -- parsing --------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[_Comp] = None
        for raw in text.splitlines():
            hdr = _COMP_HDR_RE.match(raw)
            if hdr:
                cur = _Comp(hdr.group(1))
                self.comps[cur.name] = cur
                if raw.startswith("ENTRY"):
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            if raw.startswith("}"):
                cur = None
                continue
            m = _OP_LINE_RE.match(raw)
            if m:
                name, shape_str, opcode = m.group(1), m.group(2), m.group(3)
                cur.defs[name] = shape_str
                cur.ops.append((name, shape_str, opcode.lower(), raw))

    @staticmethod
    def _is_inplace_update(comp, shape_str: str, line: str) -> bool:
        """Detect aliased-update fusions: explicit dynamic_update_slice, or
        the scan ys-stacking signature (one operand shaped exactly like the
        output, another shaped like the output minus its leading dim)."""
        if "dynamic_update_slice" in line or "dynamic-update-slice" in line:
            return True
        out_dims = tuple(_dims_of(shape_str))
        if len(out_dims) < 2:
            return False
        args = line.split("(", 1)[1] if "(" in line else ""
        shapes = [tuple(_dims_of(comp.defs[r]))
                  for r in re.findall(r"%[\w\.\-]+", args) if r in comp.defs]
        if out_dims not in shapes:
            return False
        # update operand: output minus leading dim, or leading dim -> 1
        return (out_dims[1:] in shapes) or ((1,) + out_dims[1:] in shapes)

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if not comp:
            return 1
        best = 1
        for _, _, _, line in comp.ops:
            for c in _INT_CONST_RE.findall(line):
                best = max(best, int(c))
        return best

    # -- per-computation local costs -------------------------------------
    def _analyze_ops(self) -> None:
        for comp in self.comps.values():
            for name, shape_str, opcode, line in comp.ops:
                if opcode == "dot":
                    out_elems = _elems(shape_str)
                    lc = _LHS_CONTRACT_RE.search(line)
                    contracted = 1
                    if lc:
                        # lhs operand = first %name inside the op parens
                        args = line.split("(", 1)[1]
                        ops_in = re.findall(r"%[\w\.\-]+", args)
                        if ops_in:
                            lhs_shape = comp.defs.get(ops_in[0], "")
                            dims = _dims_of(lhs_shape)
                            for di in lc.group(1).split(","):
                                if di.strip() and int(di) < len(dims):
                                    contracted *= dims[int(di)]
                    flops_here = 2.0 * out_elems * contracted
                    comp.flops += flops_here
                    mtag = _OPNAME_RE.search(line)
                    if mtag:
                        nm = mtag.group(1)
                        for tag in SCOPE_TAGS:
                            if tag in nm:
                                comp.tag_flops[tag] = (
                                    comp.tag_flops.get(tag, 0.0) + flops_here)
                                break
                elif opcode in _ELEMWISE:
                    comp.flops += _elems(shape_str)
                elif opcode.startswith(_COLLECTIVES) or any(
                        opcode == c or opcode == c + "-start"
                        for c in _COLLECTIVES):
                    if opcode.endswith("-done"):
                        continue
                    kind = opcode.replace("-start", "")
                    nbytes = shape_bytes(shape_str)
                    if kind in ("all-gather", "all-to-all", "all-reduce"):
                        # output includes the gathered result; for -start the
                        # tuple holds (input, output): take half for those
                        if shape_str.startswith("(") and kind != "all-reduce":
                            nbytes = nbytes  # tuple(in,out): keep sum/2 below
                    n = 0
                    g = _GROUPS_RE.search(line)
                    if g:
                        n = len([t for t in g.group(1).split(",") if t.strip()])
                    else:
                        gi = _GROUPS_IOTA_RE.search(line)
                        if gi:
                            n = int(gi.group(2))
                    n = max(n, 2)
                    if shape_str.startswith("("):
                        nbytes = nbytes / 2.0   # async start tuple (in, out)
                    if kind == "all-reduce":
                        ring = 2 * nbytes * (n - 1) / n
                    elif kind == "collective-permute":
                        ring = nbytes
                    else:
                        ring = nbytes * (n - 1) / n
                    comp.coll_simple += nbytes
                    comp.coll_ring += ring
                    comp.coll_count += 1
                    comp.coll_by_kind[kind] = (
                        comp.coll_by_kind.get(kind, 0) + nbytes)

                # ---- HBM-traffic proxy ----
                if opcode not in _NO_TRAFFIC and not opcode.endswith("-done"):
                    if opcode in ("dynamic-slice", "slice", "gather"):
                        # reads only the sliced window, not the whole operand
                        # (a scan body dynamic-slicing stacked weights would
                        # otherwise be charged the full stack every trip)
                        traffic = 2 * shape_bytes(shape_str)
                    elif opcode in ("dynamic-update-slice", "scatter"):
                        # in-place aliased update: touches ~2x the update
                        # region; the full buffer is NOT rewritten
                        args = line.split("(", 1)[1] if "(" in line else ""
                        refs = re.findall(r"%[\w\.\-]+", args)
                        upd = (shape_bytes(comp.defs.get(refs[1], ""))
                               if len(refs) > 1 else 0)
                        traffic = 2 * upd if upd else shape_bytes(shape_str)
                    elif opcode == "fusion" and self._is_inplace_update(
                            comp, shape_str, line):
                        # fused in-place update (explicit DUS or scan
                        # ys-stacking): buffer operand is aliased; true
                        # traffic ~ 2x the update operand, not the buffer
                        args = line.split("(", 1)[1] if "(" in line else ""
                        ops_b = sorted(
                            shape_bytes(comp.defs[r])
                            for r in re.findall(r"%[\w\.\-]+", args)
                            if r in comp.defs)
                        traffic = 2 * sum(ops_b[:-1]) if len(ops_b) > 1 \
                            else shape_bytes(shape_str)
                    elif self._is_pure_cast(comp, shape_str, opcode, line):
                        # dtype-cast of a tensor (bf16<->f32): on CPU these
                        # are materialized around every dot (no native bf16
                        # matmul); on the TPU MXU they are free/fused.
                        # Counted at 0 here, tallied in cast_bytes.
                        traffic = 0
                        self.cast_bytes_local[comp.name] = (
                            self.cast_bytes_local.get(comp.name, 0.0)
                            + shape_bytes(shape_str))
                    else:
                        out_b = shape_bytes(shape_str)
                        traffic = out_b
                        args = line.split("(", 1)[1] if "(" in line else ""
                        for ref in re.findall(r"%[\w\.\-]+", args):
                            if ref in comp.defs:
                                # cap: a fused dynamic-slice of a large stack
                                # reads a window, not the whole operand
                                traffic += min(shape_bytes(comp.defs[ref]),
                                               8 * max(out_b, 1))
                    comp.bytes += traffic
                    mtag = _OPNAME_RE.search(line)
                    if mtag:
                        nm = mtag.group(1)
                        for tag in SCOPE_TAGS:
                            if tag in nm:
                                comp.tag_bytes[tag] = (
                                    comp.tag_bytes.get(tag, 0.0) + traffic)
                                break

                # ---- call graph ----
                if opcode == "while":
                    body = _CALLEE_RE.search(line)
                    cond = _COND_RE.search(line)
                    trips = self._trip_count(cond.group(1)) if cond else 1
                    if body:
                        comp.callees.append((body.group(1), trips, False))
                    if cond:
                        comp.callees.append((cond.group(1), trips, False))
                elif opcode in ("fusion", "call", "map", "reduce",
                                "reduce-window", "scatter", "sort",
                                "select-and-scatter", "all-reduce",
                                "all-reduce-start", "reduce-scatter"):
                    cal = _CALLEE_RE.search(line)
                    if cal and opcode in ("fusion", "call", "map"):
                        comp.callees.append(
                            (cal.group(1), 1, opcode == "fusion"))
                    # to_apply of reduce/all-reduce is a scalar comp: skip
                elif opcode == "conditional":
                    br = _BRANCHES_RE.search(line)
                    if br:
                        for b in br.group(1).split(","):
                            b = b.strip()
                            if b:
                                comp.callees.append((b, 1, False))

    # -- call-graph multipliers -------------------------------------------
    def _multipliers(self):
        """Returns (exec_mult, mem_mult): exec follows all edges (flops,
        collectives); mem stops at fusion edges (fused internals are free,
        the fusion node's own operands/outputs carry the traffic)."""
        mult: Dict[str, float] = {c: 0.0 for c in self.comps}
        mem: Dict[str, float] = {c: 0.0 for c in self.comps}
        if self.entry is None:
            return mult, mem
        mult[self.entry] = 1.0
        mem[self.entry] = 1.0
        order = []
        seen = set()

        def dfs(name):
            if name in seen or name not in self.comps:
                return
            seen.add(name)
            for callee, _, _ in self.comps[name].callees:
                dfs(callee)
            order.append(name)

        dfs(self.entry)
        for name in reversed(order):
            m, mm = mult.get(name, 0.0), mem.get(name, 0.0)
            if m == 0.0 and mm == 0.0:
                continue
            for callee, k, is_fusion in self.comps[name].callees:
                if callee in mult:
                    mult[callee] += m * k
                    if not is_fusion:
                        mem[callee] += mm * k
        return mult, mem

    # -- totals -------------------------------------------------------------
    def _total(self, attr: str) -> float:
        return sum(getattr(c, attr) * self.mult.get(c.name, 0.0)
                   for c in self.comps.values())

    @property
    def flops(self) -> float:
        return self._total("flops")

    @property
    def bytes(self) -> float:
        return sum(c.bytes * self.mem_mult.get(c.name, 0.0)
                   for c in self.comps.values())

    @property
    def cast_bytes(self) -> float:
        """Total dtype-cast traffic excluded from `bytes` (CPU-backend
        bf16<->f32 legalization around dots; free on the TPU MXU)."""
        return sum(v * self.mem_mult.get(k, 0.0)
                   for k, v in self.cast_bytes_local.items())

    def by_tag(self):
        """{tag: {"flops": x, "bytes": y}} attributed via named_scope tags.
        bytes use mem multipliers; flops use exec multipliers."""
        out = {}
        for c in self.comps.values():
            me, mm = self.mult.get(c.name, 0.0), self.mem_mult.get(c.name, 0.0)
            for t, v in c.tag_flops.items():
                out.setdefault(t, {"flops": 0.0, "bytes": 0.0})
                out[t]["flops"] += v * me
            for t, v in c.tag_bytes.items():
                out.setdefault(t, {"flops": 0.0, "bytes": 0.0})
                out[t]["bytes"] += v * mm
        return out

    def collectives(self) -> CollectiveStats:
        st = CollectiveStats()
        for c in self.comps.values():
            m = self.mult.get(c.name, 0.0)
            st.bytes_simple += c.coll_simple * m
            st.bytes_ring += c.coll_ring * m
            st.count += int(c.coll_count * m)
            for k, v in c.coll_by_kind.items():
                st.by_kind[k] = st.by_kind.get(k, 0) + v * m
                st.by_kind_count[k] = st.by_kind_count.get(k, 0) + int(m)
        return st


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-start" in line and "-done" not in line:
            pass  # count the start (has the shape); skip matching -done below
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        nbytes = shape_bytes(shape_str)
        # replica group size for the ring model
        n = 0
        g = _GROUPS_RE.search(line)
        if g:
            n = len([t for t in g.group(1).split(",") if t.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        n = max(n, 2)
        if kind == "all-reduce":
            ring = 2 * nbytes * (n - 1) / n
        elif kind == "collective-permute":
            ring = nbytes
        else:
            ring = nbytes * (n - 1) / n
        stats.bytes_simple += nbytes
        stats.bytes_ring += ring
        stats.count += 1
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + nbytes
        stats.by_kind_count[kind] = stats.by_kind_count.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_ring: float
    coll_count: int
    coll_by_kind: Dict[str, int]
    model_flops: float
    per_device_mem: Optional[float]
    raw_cost_flops: float = 0.0       # compiled.cost_analysis() (loop bodies x1)
    raw_cost_bytes: float = 0.0
    cast_bytes: float = 0.0           # excluded CPU-legalization cast traffic

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to the compute roofline: time the chips are
        doing model math vs total bound time (higher is better)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_time if self.bound_time else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "coll_ring": self.coll_ring,
            "coll_count": self.coll_count, "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_mem_bytes": self.per_device_mem,
            "raw_cost_flops": self.raw_cost_flops,
            "raw_cost_bytes": self.raw_cost_bytes,
            "cast_bytes": self.cast_bytes,
        }


def analyze(arch: str, shape: str, mesh_name: str, chips: int, compiled,
            lowered, model_flops: float) -> Roofline:
    """Roofline terms from the compiled per-device SPMD module.

    Primary figures come from the trip-count-aware :class:`HloCost`;
    ``compiled.cost_analysis()`` raw values (which undercount loop bodies)
    are preserved in the row for cross-reference.
    """
    hlo = compiled.as_text()
    cm = HloCost(hlo)
    per_dev_flops = cm.flops
    per_dev_bytes = cm.bytes
    stats = cm.collectives()
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = (getattr(ma, "argument_size_in_bytes", 0)
                   + getattr(ma, "output_size_in_bytes", 0)
                   + getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        pass
    r = Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                 hlo_flops=per_dev_flops * chips,
                 hlo_bytes=per_dev_bytes * chips,
                 coll_bytes=stats.bytes_simple * chips,
                 coll_ring=stats.bytes_ring * chips,
                 coll_count=stats.count, coll_by_kind=stats.by_kind,
                 model_flops=model_flops, per_device_mem=mem)
    cost = compiled.cost_analysis() or {}
    r.raw_cost_flops = float(cost.get("flops", 0.0))
    r.raw_cost_bytes = float(cost.get("bytes accessed", 0.0))
    r.cast_bytes = cm.cast_bytes * chips
    return r


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D train / 2*N*D inference with N = active params, D = tokens."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: one token/seq
