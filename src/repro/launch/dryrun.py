import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh and extract memory/cost/collective roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multi-pod] [--out results.json]

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, or unsupported collectives all fail here.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (SHAPES, HornConfig, RunConfig, get_model_config,
                                list_archs)
from repro.core import steps
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh

# long_500k applicability (DESIGN.md §Arch-applicability): run only for archs
# with sub-quadratic / windowed sequence structure.
LONG_OK = {"mamba2-2.7b", "jamba-1.5-large-398b", "gemma2-27b", "gemma3-4b"}


def applicable(arch: str, shape_name: str):
    if shape_name == "long_500k" and arch not in LONG_OK:
        return False, "pure full-attention arch: 500k ctx skipped (DESIGN.md)"
    if arch == "whisper-base" and shape_name == "long_500k":
        return False, "enc-dec audio: 500k decoder ctx is architecturally moot"
    return True, ""


def make_run(arch: str, shape_name: str, multi_pod: bool) -> RunConfig:
    cfg = get_model_config(arch)
    shape = SHAPES[shape_name]
    # Horn parallel dropout is a training-time feature; serving cells run eval.
    horn = HornConfig(enabled=shape.kind == "train")
    return RunConfig(model=cfg, shape=shape, horn=horn, optimizer="adamw",
                     learning_rate=3e-4, momentum=0.9, multi_pod=multi_pod)


def lower_cell(run: RunConfig, mesh):
    """Returns (lowered, compiled) for the cell's step function."""
    kind = run.shape.kind
    if kind == "train":
        jitted, _ = steps.make_train_step(run, mesh)
        state = jax.eval_shape(lambda: steps.init_state(
            jax.random.key(0), run))
        batch = steps.input_specs(run)
        with mesh:
            lowered = jitted.lower(state, batch)
    elif kind == "prefill":
        jitted, _ = steps.make_prefill_step(run, mesh)
        pstruct = jax.eval_shape(
            lambda: steps.init_state(jax.random.key(0), run))["params"]
        batch = steps.input_specs(run)
        with mesh:
            lowered = jitted.lower(pstruct, batch)
    else:  # decode
        jitted, info = steps.make_decode_step(run, mesh)
        pstruct = jax.eval_shape(
            lambda: steps.init_state(jax.random.key(0), run))["params"]
        dspec = steps.decode_input_specs(run)
        args = (pstruct, info["cache_struct"], dspec["tokens"], dspec["pos"])
        if run.model.is_encoder_decoder:
            args = args + (dspec["encoder_out"],)
        with mesh:
            lowered = jitted.lower(*args)
    compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True):
    ok, why = applicable(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    t0 = time.time()
    try:
        run = make_run(arch, shape_name, multi_pod)
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        lowered, compiled = lower_cell(run, mesh)
        mf = analysis.model_flops_estimate(run.model, run.shape)
        roof = analysis.analyze(arch, shape_name, mesh_name, chips,
                                compiled, lowered, mf)
        row = roof.row()
        row["status"] = "ok"
        row["compile_s"] = time.time() - t0
        try:
            ma = compiled.memory_analysis()
            row["memory_analysis"] = str(ma)
        except Exception:
            row["memory_analysis"] = None
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] OK "
                  f"compile={row['compile_s']:.1f}s "
                  f"flops={row['hlo_flops']:.3e} bytes={row['hlo_bytes']:.3e} "
                  f"coll={row['coll_bytes']:.3e} ({row['coll_count']} ops) "
                  f"dominant={row['dominant']}")
            print("  memory_analysis:", row["memory_analysis"])
        return row
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "compile_s": time.time() - t0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ([a for a in list_archs() if a != "horn-mnist"]
             if args.arch == "all" else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    rows = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rows.append(run_cell(arch, shape, mp))
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    print(f"\ndry-run cells: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print("wrote", args.out)
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
