"""Mesh construction and logical-axis sharding rules.

The production mesh is ``(data=16, model=16)`` per pod (256 chips, TPU v5e) and
``(pod=2, data=16, model=16)`` for the 2-pod dry-run.  Model code never touches
mesh axes directly: it annotates tensors with *logical* axes ("batch", "heads",
"ffn", ...) through a :class:`ShardingCtx`, and the rules below map logical →
physical with divisibility-aware fallbacks (e.g. 8 kv-heads cannot shard over a
16-way model axis → replicate; 56 q-heads cannot → shard head_dim instead).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat shard_map: newer jax exposes ``jax.shard_map`` with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map.shard_map`` with
    ``check_rep``.  Model code calls this wrapper only."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh (function, so importing never inits jax)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A mesh over whatever devices exist (CPU tests: usually 1x1)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def axis_sizes(mesh: Optional[Mesh]) -> dict:
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sharding_rules(cfg: ModelConfig, mesh: Optional[Mesh],
                   shape=None) -> dict:
    """Logical-axis -> mesh-axis mapping for one architecture on one mesh.

    ``shape`` (a ShapeConfig) enables shape-aware fallbacks: a decode cell
    with global_batch=1 cannot shard batch over `data`, so the KV-cache
    sequence axis takes the data axis instead (sequence-parallel decode).
    """
    sizes = axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    data_n = sizes.get("data", 1)
    has_pod = "pod" in sizes

    def on_model(dim: int):
        return "model" if model_n > 1 and dim > 0 and dim % model_n == 0 \
            else None

    def on_data(dim: int):
        return "data" if data_n > 1 and dim > 0 and dim % data_n == 0 \
            else None

    heads = on_model(cfg.num_heads)
    kv_heads = on_model(cfg.num_kv_heads)
    # GQA fallback: if q-heads don't shard, shard head_dim (llama4: 40H, llava: 56H)
    head_dim = on_model(cfg.head_dim) if heads is None else None
    if heads is None and head_dim is not None:
        kv_heads = None  # k/v share the head_dim sharding instead

    d_in = cfg.ssm_expand * cfg.d_model
    rules = {
        # activations
        "batch": ("pod", "data") if has_pod else ("data",),
        "seq": None,
        "seq_model": on_model(1 << 30),   # opt-in KV-sequence sharding (decode)
        "act_embed": None,
        "act_ffn": on_model(cfg.d_ff),
        "heads": heads,
        "kv_heads": kv_heads,
        "head_dim": head_dim,
        "kv_head_dim": head_dim if kv_heads is None else None,
        # weights (FSDP on the d_model dim over `data`; TP on the wide dim)
        "embed": on_data(cfg.d_model),
        "ffn": on_model(cfg.d_ff),
        "moe_ffn": on_model(cfg.moe_ff) if cfg.num_experts else None,
        "vocab": on_model(cfg.vocab_size),
        "experts": on_model(cfg.num_experts) if cfg.num_experts else None,
        "ssm_inner": on_model(d_in) if cfg.ssm_state else None,
        "ssm_heads": (on_model(d_in // cfg.ssm_head_dim)
                      if cfg.ssm_state else None),
        "ssm_state": None,
        "layers": None,
        "conv": None,
        "noshard": None,
    }
    # KV-cache sequence axis: prefer kv-head sharding; when kv heads do not
    # divide the model axis (qwen1.5: 20, llava/jamba: 8 on 16), shard the
    # cache's *sequence* dim over model instead (flash-decode style) — this
    # is what stops GSPMD from all-gathering the whole cache (EXPERIMENTS
    # §Perf, hillclimb 2).
    rules["kv_seq"] = None
    if shape is not None:
        if (shape.kind == "decode" and rules["kv_heads"] is None
                and shape.seq_len % max(model_n, 1) == 0):
            rules["kv_seq"] = on_model(shape.seq_len)
        # Serving shapes: weights are read-only — FSDP's per-layer
        # all-gathers buy nothing, so replicate over `data` (hillclimb 1/2).
        if shape.kind != "train":
            rules["embed"] = None
        # Prefill/train with unshardable heads: sequence-parallel attention
        # (shard q-sequence over model; no score psum needed) instead of
        # head_dim sharding, which made GSPMD gather/psum huge score tensors.
        rules["sp_seq"] = None
        if (shape.kind in ("prefill", "train") and rules["heads"] is None
                and shape.seq_len % max(model_n, 1) == 0):
            rules["sp_seq"] = on_model(shape.seq_len)
            rules["head_dim"] = None
            rules["kv_head_dim"] = None
            # Megatron-style sequence parallelism: keep the residual stream
            # seq-sharded everywhere (norms/elementwise local; K/V gathered
            # in bf16 — 40x smaller than the full-activation f32 gathers the
            # SP<->TP boundary otherwise produces each sublayer).  Measured
            # win on dense archs; REGRESSION on MoE (expert dispatch wants
            # token-replicated rows) — so gated to num_experts == 0.
            if cfg.num_experts == 0:
                rules["seq"] = rules["sp_seq"]
        dp = data_n * sizes.get("pod", 1)
        if dp > 1 and shape.global_batch % dp != 0:
            rules["batch"] = None
            # sequence-parallel fallback (long-context decode, batch 1)
            if shape.kind == "decode" and shape.seq_len % data_n == 0:
                rules["seq"] = "data"
    return rules


@dataclass
class ShardingCtx:
    """Applies logical-axis sharding constraints inside model code."""

    mesh: Optional[Mesh] = None
    rules: dict = field(default_factory=dict)

    def spec(self, *axes) -> P:
        entries, used = [], set()
        for a in axes:
            m = self.rules.get(a) if a is not None else None
            if m is None:
                entries.append(None)
                continue
            ms = m if isinstance(m, tuple) else (m,)
            ms = tuple(x for x in ms if x not in used)
            used.update(ms)
            entries.append(ms if len(ms) != 1 else ms[0])
            if not ms:
                entries[-1] = None
        return P(*entries)

    def sharding(self, *axes) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*axes))

    def constrain(self, x, *axes):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(*axes))

    @property
    def dp_size(self) -> int:
        s = axis_sizes(self.mesh)
        return s.get("data", 1) * s.get("pod", 1)


def null_ctx() -> ShardingCtx:
    return ShardingCtx(mesh=None, rules={})


def is_axes_leaf(x) -> bool:
    """An axes annotation: a (possibly empty) tuple of axis names / None.
    (A (k, v) cache pair is a tuple of tuples — NOT a leaf.)"""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def tree_shardings(axes_tree, ctx: ShardingCtx):
    """Map a pytree of logical-axes tuples to NamedShardings (or None off-mesh)."""
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, axes_tree, is_leaf=is_axes_leaf)
    return jax.tree.map(lambda ax: ctx.sharding(*ax), axes_tree,
                        is_leaf=is_axes_leaf)
