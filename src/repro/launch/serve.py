"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --batch 4 --prompt-len 64 --gen 16

Runs the real production serving path (pjit prefill -> pjit one-token decode
with donated sharded KV cache) on reduced configs in this container; the
full-config versions are proven by the decode cells of the dry-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (HornConfig, RunConfig, ShapeConfig,
                                get_model_config, list_archs, reduced)
from repro.core import steps as S
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_model_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    if cfg.family == "mlp":
        raise SystemExit("horn-mnist is a classifier; use launch.train")
    max_len = args.prompt_len + args.gen
    mesh = make_test_mesh()
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", "decode", max_len, args.batch),
                    horn=HornConfig(enabled=False))

    params = api.model_init(jax.random.key(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    text_len = args.prompt_len - (cfg.num_patches or 0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, max(1, text_len))),
        jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)

    pre, _ = S.make_prefill_step(run, mesh)
    t0 = time.time()
    logits, prefill_cache, enc = pre(params, batch)
    logits.block_until_ready()
    print(f"prefill [{args.batch} x {args.prompt_len}]: "
          f"{time.time() - t0:.2f}s")

    # right-pad the prefill cache into the decode buffer
    dec, info = S.make_decode_step(run, mesh)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         info["cache_struct"])

    def splice(buf, pre_arr):
        if (buf.ndim == pre_arr.ndim and buf.ndim >= 4
                and pre_arr.shape[-2:] == buf.shape[-2:]):
            seq_ax = buf.ndim - 3
            if pre_arr.shape[seq_ax] <= buf.shape[seq_ax]:
                pad = [(0, 0)] * buf.ndim
                pad[seq_ax] = (0, buf.shape[seq_ax] - pre_arr.shape[seq_ax])
                return jnp.pad(pre_arr, pad).astype(buf.dtype)
        return pre_arr.astype(buf.dtype)   # SSM states / conv tails: as-is

    cache = jax.tree.map(splice, cache, prefill_cache)

    def sample(lg, key):
        if args.temperature <= 0:
            return jnp.argmax(lg, -1)
        return jax.random.categorical(key, lg / args.temperature)

    token = sample(logits, jax.random.key(1))[:, None].astype(jnp.int32)
    out_tokens = [token]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        dargs = (params, cache, token, pos)
        if cfg.is_encoder_decoder:
            dargs = dargs + (enc.astype(jnp.bfloat16),)
        lg, cache = dec(*dargs)
        token = sample(lg, jax.random.fold_in(jax.random.key(1), i)
                       )[:, None].astype(jnp.int32)
        out_tokens.append(token)
    jax.block_until_ready(token)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decode: {args.gen - 1} steps in {dt:.2f}s "
          f"({args.batch * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("generated token ids (first row):", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
