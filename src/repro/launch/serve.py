"""Serving launcher: continuous-batching engine under a synthetic load.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --stream poisson --requests 32

Drives ``repro.serving.Engine`` (paged KV cache + FCFS continuous batching
+ chunked prefill) from a synthetic request stream: Poisson arrivals with
mixed prompt lengths, each request joining the batch the moment a slot and
pages free up and leaving on completion.  Every engine tick is one unified
device call over a fixed token budget (``--budget``), so a long admission
never stalls the running batch for more than one tick.  Reports decode
tok/s, time-to-first-token, p50/p99 end-to-end latency, and preemptions
(pool pressure under ``--policy on_demand`` evicts the youngest sequence
back to the queue instead of killing the server).

``--stream batch`` submits everything at t=0 (a closed-loop throughput
measurement); ``--stream poisson`` is the open-loop latency measurement.
``--long-frac`` pins that fraction of prompts at ``--max-prompt`` — the
adversarial mix that used to stall decode for whole-prompt prefills.
Exits with status 2 only on a genuinely unservable request (EngineOOM:
one sequence can never fit the pool).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs.base import get_model_config, list_archs, reduced
from repro.models import api
from repro.serving import Engine, EngineConfig, EngineOOM


def make_requests(n: int, vocab_size: int, rng: np.random.Generator, *,
                  stream: str = "poisson", rate: float = 16.0,
                  max_prompt: int = 64, gen: int = 16,
                  long_frac: float = 0.0):
    """(arrival_time, prompt, max_new) triples: Poisson arrivals (or all at
    t=0 for ``stream="batch"``), mixed prompt lengths (log-uniform between 4
    and ``max_prompt``), per-request max_new drawn in [gen/2, gen].
    ``long_frac`` of the prompts are pinned at ``max_prompt`` exactly — the
    adversarial long-prompt mix for chunked-prefill benchmarks.  Shared by
    the launcher and benchmarks/serving_bench.py so their loads stay
    comparable."""
    out, t = [], 0.0
    for _ in range(n):
        if stream == "poisson":
            t += rng.exponential(1.0 / rate)
        if long_frac > 0 and rng.uniform() < long_frac:
            plen = max_prompt
        else:
            lo, hi = np.log(4), np.log(max_prompt)
            plen = int(np.exp(rng.uniform(lo, hi)))
        prompt = rng.integers(0, vocab_size, (max(1, plen),)).astype(np.int32)
        g = int(rng.integers(max(1, gen // 2), gen + 1))
        out.append((t, prompt, g))
    return out


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--stream", choices=["poisson", "batch"], default="poisson")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="poisson arrival rate (requests/s)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pages", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16,
                    help="max new tokens (per-request draw in [gen/2, gen])")
    ap.add_argument("--budget", type=int, default=256,
                    help="tokens per unified tick (decode + prompt chunks)")
    ap.add_argument("--long-frac", type=float, default=0.0,
                    help="fraction of prompts pinned at --max-prompt")
    ap.add_argument("--policy", choices=["reserve", "on_demand"],
                    default="on_demand")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_model_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    if cfg.family == "mlp":
        raise SystemExit("horn-mnist is a classifier; use launch.train")

    ecfg = EngineConfig(
        num_slots=args.slots, num_pages=args.pages, page_size=args.page_size,
        max_prompt_len=-(-args.max_prompt // args.page_size) * args.page_size,
        max_new_tokens=args.gen, token_budget=max(args.budget, args.slots),
        temperature=args.temperature, seed=args.seed, policy=args.policy)
    import jax
    params = api.model_init(jax.random.key(args.seed), cfg)
    try:
        engine = Engine(cfg, params, ecfg)
    except ValueError as e:
        raise SystemExit(f"{args.arch}: {e}")

    rng = np.random.default_rng(args.seed)
    pending = make_requests(args.requests, cfg.vocab_size, rng,
                            stream=args.stream, rate=args.rate,
                            max_prompt=args.max_prompt, gen=args.gen,
                            long_frac=args.long_frac)
    print(f"serving {args.requests} requests ({args.stream} stream, "
          f"{args.slots} slots, {args.pages}x{args.page_size}-token pages, "
          f"budget {ecfg.token_budget} tok/tick, policy={args.policy})")

    t0 = time.monotonic()
    max_running = 0
    try:
        while pending or engine.sched.has_work():
            now = time.monotonic() - t0
            while pending and pending[0][0] <= now:
                at, prompt, gen = pending.pop(0)
                try:
                    engine.submit(prompt, gen, arrival_time=at)
                except ValueError as e:
                    print(f"FATAL: infeasible request — {e}", file=sys.stderr)
                    sys.exit(2)
            if not engine.sched.has_work():
                time.sleep(min(0.005, max(0.0, pending[0][0] - now)))
                continue
            for req in engine.step(time.monotonic() - t0,
                                   tick_clock=lambda: time.monotonic() - t0):
                pre = f"  ({req.num_preemptions}x preempted)" \
                    if req.num_preemptions else ""
                print(f"  req {req.id:3d} done: prompt {req.prompt_len:3d} "
                      f"+{len(req.out_tokens):3d} tok  "
                      f"ttft {req.t_first_token - req.arrival_time:6.3f}s  "
                      f"latency {req.t_done - req.arrival_time:6.3f}s{pre}")
            max_running = max(max_running, len(engine.sched.running))
    except EngineOOM as e:
        print(f"FATAL: unservable request — {e}", file=sys.stderr)
        sys.exit(2)
    wall = time.monotonic() - t0

    done = engine.sched.finished
    assert len(done) == args.requests, (len(done), args.requests)
    ttft = [r.t_first_token - r.arrival_time for r in done]
    lat = [r.t_done - r.arrival_time for r in done]
    total_new = sum(len(r.out_tokens) for r in done)
    print(f"\n{len(done)} requests in {wall:.2f}s  "
          f"(max {max_running}/{args.slots} slots concurrent)")
    print(f"throughput: {total_new / max(wall, 1e-9):.1f} tok/s "
          f"({engine.steps} ticks, "
          f"{engine.generated_tokens / max(engine.steps, 1):.1f} tok/tick, "
          f"{engine.prefill_tokens} prefill tok)")
    print(f"TTFT    p50 {percentile(ttft, 50):.3f}s  "
          f"p99 {percentile(ttft, 99):.3f}s")
    print(f"latency p50 {percentile(lat, 50):.3f}s  "
          f"p99 {percentile(lat, 99):.3f}s")
    print(f"page-pool peak utilization: {engine.peak_utilization:.0%}  "
          f"preemptions: {engine.preemptions}")


if __name__ == "__main__":
    main()
