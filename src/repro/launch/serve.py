"""Serving launcher: continuous-batching engine under a synthetic load.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --stream poisson --requests 32

Drives ``repro.serving.Engine`` (paged KV cache + FCFS continuous batching
+ chunked prefill) from a synthetic request stream: Poisson arrivals with
mixed prompt lengths, each request joining the batch the moment a slot and
pages free up and leaving on completion.  Every engine tick is one unified
device call over a fixed token budget (``--budget``), so a long admission
never stalls the running batch for more than one tick.  Reports decode
tok/s, time-to-first-token, p50/p99 end-to-end latency, and preemptions
(pool pressure under ``--policy on_demand`` evicts the youngest sequence
back to the queue instead of killing the server).

``--stream batch`` submits everything at t=0 (a closed-loop throughput
measurement); ``--stream poisson`` is the open-loop latency measurement.
``--long-frac`` pins that fraction of prompts at ``--max-prompt`` — the
adversarial mix that used to stall decode for whole-prompt prefills.
Exits with status 2 only on a genuinely unservable request (EngineOOM:
one sequence can never fit the pool).

``--submodels G`` serves G Horn parallel circuits (a ModelBank of fixed
sub-model masks over one shared parent) behind the same engine: requests
are routed per ``--router`` and co-batch across circuits in every tick;
``--ensemble-frac`` of requests instead fan across ALL circuits and
combine logits on device (``--combine``).

``--prefix-cache`` (default on) content-addresses full KV pages so
identical prompt prefixes are prefilled once and adopted (refcounted,
copy-on-write) by later requests; an ensemble's shared prompt context is
prefilled once by its leader and forked into all G members.

``--speculate K`` turns on speculative decoding: a materialized Horn
small circuit (``--draft-circuit`` of the serving bank, or a draft-only
``--draft-keep`` bank when running the dense parent) proposes K tokens
per decode tick in one jitted draft call, and the parent verifies all
K+1 positions inside its one budgeted call — greedy output stays
byte-identical to non-speculative serving, it just lands up to K+1
tokens per tick.

``--kv-dtype int8`` stores the paged KV pools quantized (per-(page,
kv-head) f32 scale sidecars beside the pools, dequantized in-register
inside the kernels): ~2x sequences at equal HBM and fewer pool-pressure
preemptions, with a bounded greedy-decode divergence instead of the f32
path's byte-identity.  ``--pages-per-step N`` makes the paged kernels
fetch N KV pages per grid step (double-buffered page DMAs on TPU) —
bit-identical output for any N.

Observability (``repro.serving.observability``): ``--stats-every N``
prints a periodic stats line off the engine's telemetry snapshot;
``--trace-out trace.json`` records every tick's plan / host-prep /
device-step / commit phases plus one track per slot and writes Chrome
Trace Event JSON (open in https://ui.perfetto.dev); ``--slo-class
name:ttft:latency`` configures per-class SLO targets and reports
attainment at exit.

Continuous perf harness: ``--record-trace trace.jsonl`` writes the
exact request stream this run served (arrival offsets, prompt token
ids, budgets, ensemble decisions) as a versioned JSONL trace;
``--replay trace.jsonl`` re-serves a recorded stream on the
deterministic virtual clock (arrivals at their recorded offsets, each
tick advancing ``--tick-dt`` seconds) — greedy token streams are
byte-identical run-to-run, which is what ``benchmarks/regression.py``
gates on.  Live anomaly alerts (tick-duration spikes, SLO burn rate,
pool leaks, accept-rate collapse, post-warmup recompiles) print in the
exit report and land in the ``--trace-out`` export.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs.base import HornConfig, get_model_config, list_archs, \
    reduced
from repro.models import api
from repro.serving import Engine, EngineConfig, EngineOOM, ModelBank, Router
from repro.serving.observability import (Telemetry, TraceRecorder,
                                         load_trace, parse_slo_class,
                                         percentile, replay)
from repro.serving.observability.replay import DEFAULT_TICK_DT


def build_draft(cfg, params, bank, *, speculate: int, draft_circuit: int,
                draft_keep: float, mask_block: int, seed: int):
    """The draft circuit for ``--speculate K``: cut from the serving bank
    when one exists (the drafted tokens are verified per-slot under each
    request's own circuit masks, so any bank circuit is a valid proposer),
    else from a throwaway draft-only bank over the same parent weights at
    ``--draft-keep`` (the dense parent is the verifier)."""
    if speculate <= 0:
        return None
    if bank is not None:
        return bank.draft_model(draft_circuit, params)
    horn = HornConfig(enabled=True, keep_hidden=draft_keep, keep_input=1.0,
                      block_size=mask_block)
    dbank = ModelBank(cfg, horn, draft_circuit + 1, seed=seed)
    return dbank.draft_model(draft_circuit, params)


def make_requests(n: int, vocab_size: int, rng: np.random.Generator, *,
                  stream: str = "poisson", rate: float = 16.0,
                  max_prompt: int = 64, gen: int = 16,
                  long_frac: float = 0.0, shared_prefix: int = 0):
    """(arrival_time, prompt, max_new) triples: Poisson arrivals (or all at
    t=0 for ``stream="batch"``), mixed prompt lengths (log-uniform between 4
    and ``max_prompt``), per-request max_new drawn in [gen/2, gen].
    ``long_frac`` of the prompts are pinned at ``max_prompt`` exactly — the
    adversarial long-prompt mix for chunked-prefill benchmarks.
    ``shared_prefix`` prepends one fixed system prompt of that many tokens
    to EVERY request (unique tails keep total length <= max_prompt) — the
    shared-system-prompt mix the prefix cache is built for.  Shared by the
    launcher and benchmarks/serving_bench.py so their loads stay
    comparable."""
    if not 0 <= shared_prefix <= max_prompt - 4:
        raise ValueError(
            f"shared_prefix ({shared_prefix}) must leave >= 4 tokens of "
            f"unique tail under max_prompt ({max_prompt})")
    out, t = [], 0.0
    system = rng.integers(0, vocab_size,
                          (shared_prefix,)).astype(np.int32)
    for _ in range(n):
        if stream == "poisson":
            t += rng.exponential(1.0 / rate)
        room = max_prompt - shared_prefix
        if long_frac > 0 and rng.uniform() < long_frac:
            plen = room
        else:
            lo, hi = np.log(min(4, room)), np.log(room)
            plen = int(np.exp(rng.uniform(lo, hi)))
        tail = rng.integers(0, vocab_size, (max(1, plen),)).astype(np.int32)
        prompt = np.concatenate([system, tail]) if shared_prefix else tail
        g = int(rng.integers(max(1, gen // 2), gen + 1))
        out.append((t, prompt, g))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs(),
                    help="model architecture; required unless --replay "
                         "(the trace header records the arch it was "
                         "recorded on)")
    ap.add_argument("--stream", choices=["poisson", "batch"], default="poisson")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="poisson arrival rate (requests/s)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pages", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16,
                    help="max new tokens (per-request draw in [gen/2, gen])")
    ap.add_argument("--budget", type=int, default=256,
                    help="tokens per unified tick (decode + prompt chunks)")
    ap.add_argument("--long-frac", type=float, default=0.0,
                    help="fraction of prompts pinned at --max-prompt")
    ap.add_argument("--policy", choices=["reserve", "on_demand"],
                    default="on_demand")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="content-addressed KV page reuse + COW: identical "
                         "prompt prefixes prefill once, ensembles share "
                         "their prompt pages across all circuits "
                         "(--no-prefix-cache re-prefills per request)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-dtype", choices=["bfloat16", "float32", "int8"],
                    default="bfloat16",
                    help="paged KV pool dtype.  int8 stores quantized pages "
                         "plus per-(page, kv-head) f32 scale sidecars: "
                         "~2x sequences at equal HBM, bounded-error decode "
                         "(dequantized in-register inside the kernel)")
    ap.add_argument("--pages-per-step", type=int, default=1,
                    help="KV pages fetched per paged-attention grid step "
                         "(>1 double-buffers page DMAs for more HBM "
                         "bandwidth; output is bit-identical for any value)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decoding: a materialized draft "
                         "circuit proposes K tokens per decode tick, the "
                         "parent verifies all K+1 positions in its one "
                         "budgeted call (0 = off)")
    ap.add_argument("--draft-circuit", type=int, default=0,
                    help="bank circuit the draft is materialized from")
    ap.add_argument("--draft-keep", type=float, default=0.875,
                    help="FFN keep rate of the draft-only bank when "
                         "--submodels 0 (acceptance tracks draft<->parent "
                         "agreement: keep it high for untrained parents, "
                         "Horn-trained circuits accept well lower)")
    ap.add_argument("--submodels", type=int, default=0,
                    help="serve G Horn circuits from one ModelBank "
                         "(0 = single dense parent)")
    ap.add_argument("--router", choices=["least_loaded", "hash"],
                    default="least_loaded")
    ap.add_argument("--ensemble-frac", type=float, default=0.0,
                    help="fraction of requests fanned across ALL circuits "
                         "with on-device logit combining")
    ap.add_argument("--combine", choices=["mean_logit", "majority_vote"],
                    default="mean_logit")
    ap.add_argument("--keep", type=float, default=0.5,
                    help="per-circuit FFN hidden keep rate (paper: 0.5)")
    ap.add_argument("--mask-block", type=int, default=16,
                    help="mask block size in hidden units (reduced configs "
                         "need <= d_ff/4 for distinct circuits)")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--record-trace", metavar="PATH", default=None,
                    help="write the served request stream (arrivals, "
                         "prompt ids, budgets, ensemble decisions) as a "
                         "versioned JSONL traffic trace")
    ap.add_argument("--replay", metavar="PATH", default=None,
                    help="serve a recorded trace on the deterministic "
                         "virtual clock instead of a synthetic stream "
                         "(--requests/--stream/--rate are ignored)")
    ap.add_argument("--tick-dt", type=float, default=DEFAULT_TICK_DT,
                    help="virtual seconds per tick during --replay "
                         f"(default {DEFAULT_TICK_DT})")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record the per-tick timeline (plan / host-prep / "
                         "device-step / commit phases + one track per slot) "
                         "and write Chrome Trace Event JSON here — open in "
                         "https://ui.perfetto.dev or chrome://tracing")
    ap.add_argument("--stats-every", type=int, default=0, metavar="TICKS",
                    help="print a periodic stats line every N engine ticks "
                         "(0 = off)")
    ap.add_argument("--slo-class", action="append", default=[],
                    metavar="NAME:TTFT:LAT",
                    help="SLO targets (seconds; '-' leaves a bound unset), "
                         "e.g. 'default:0.5:5'; repeatable.  Launcher "
                         "traffic is scored under class 'default'; "
                         "Engine.submit(slo_class=...) routes other "
                         "classes.  Attainment is reported at exit.")
    ap.add_argument("--sanitize", action="store_true",
                    help="runtime sanitizers (hornlint's dynamic twin): "
                         "jax_debug_nans, strict rank promotion, and "
                         "per-tick pool/block-table invariant checks.  "
                         "Pure-host overhead, excluded from bench gates; "
                         "exits 3 if any invariant alert fires.")
    args = ap.parse_args()

    if args.arch is None:
        if not args.replay:
            ap.error("--arch is required (unless --replay)")
        args.arch = load_trace(args.replay)[1].get("arch")
        if args.arch is None:
            ap.error(f"--arch: {args.replay} records no arch in its "
                     f"header meta; pass --arch explicitly")

    cfg = get_model_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    if cfg.family == "mlp":
        raise SystemExit("horn-mnist is a classifier; use launch.train")

    ecfg = EngineConfig(
        num_slots=args.slots, num_pages=args.pages, page_size=args.page_size,
        max_prompt_len=-(-args.max_prompt // args.page_size) * args.page_size,
        max_new_tokens=args.gen, token_budget=max(args.budget, args.slots),
        temperature=args.temperature, seed=args.seed, policy=args.policy,
        prefix_cache=args.prefix_cache, speculate_k=args.speculate,
        kv_dtype=args.kv_dtype, pages_per_step=args.pages_per_step)
    sanitizer = None
    if args.sanitize:
        from repro.analysis.sanitize import Sanitizer
        Sanitizer.install_jax_guards()      # before anything jits
        sanitizer = Sanitizer()
    import jax
    params = api.model_init(jax.random.key(args.seed), cfg)
    bank = router = None
    if args.submodels > 0:
        if args.submodels > args.slots and args.ensemble_frac > 0:
            raise SystemExit("ensemble mode needs --slots >= --submodels")
        horn = HornConfig(enabled=True, keep_hidden=args.keep,
                          keep_input=1.0, block_size=args.mask_block)
        bank = ModelBank(cfg, horn, args.submodels, seed=args.seed)
        router = Router(args.submodels, policy=args.router)
    try:
        telemetry = Telemetry(
            timeline=args.trace_out is not None,
            slo_classes=[parse_slo_class(s) for s in args.slo_class])
        draft = build_draft(cfg, params, bank, speculate=args.speculate,
                            draft_circuit=args.draft_circuit,
                            draft_keep=args.draft_keep,
                            mask_block=args.mask_block, seed=args.seed)
        engine = Engine(cfg, params, ecfg, bank=bank, router=router,
                        draft=draft, telemetry=telemetry)
    except ValueError as e:
        raise SystemExit(f"{args.arch}: {e}")
    if sanitizer is not None:
        sanitizer.attach(engine)

    if args.replay:
        records, meta = load_trace(args.replay)
        if meta.get("arch") not in (None, args.arch):
            print(f"WARNING: trace was recorded on arch "
                  f"{meta['arch']!r}, replaying on {args.arch!r}",
                  file=sys.stderr)
        print(f"replaying {len(records)} requests from {args.replay} "
              f"(virtual clock, {args.tick_dt * 1e3:g}ms/tick)")
        try:
            result = replay(engine, records, tick_dt=args.tick_dt)
        except EngineOOM as e:
            print(f"FATAL: unservable request — {e}", file=sys.stderr)
            sys.exit(2)
        s = result.summary()
        wall = sum(result.tick_wall_s)
        print(f"\n{result.requests} requests in {result.virtual_s:.2f} "
              f"virtual s ({wall:.2f}s host compute, "
              f"{result.ticks} ticks)")
        print(f"throughput: {s['decode_tok_s_p10'] or 0:.1f} tok/s "
              f"(pooled-p10 tick estimate)  "
              f"{result.generated_tokens} tokens  "
              f"digest {result.token_digest[:16]}")
        print(f"TTFT    p50 {s['ttft_p50_s']:.3f}s  "
              f"p99 {s['ttft_p99_s']:.3f}s  (virtual clock)")
        print(f"latency p50 {s['latency_p50_s']:.3f}s  "
              f"p99 {s['latency_p99_s']:.3f}s")
        _tail_report(engine, args, bank, wall)
        _exit_sanitize(engine)
        return

    rng = np.random.default_rng(args.seed)
    recorder = TraceRecorder(meta={
        "arch": args.arch, "seed": args.seed, "stream": args.stream,
        "rate": args.rate, "max_prompt": args.max_prompt,
        "gen": args.gen, "long_frac": args.long_frac,
        **engine.obs.engine_config,
    }) if args.record_trace else None
    pending = make_requests(args.requests, cfg.vocab_size, rng,
                            stream=args.stream, rate=args.rate,
                            max_prompt=args.max_prompt, gen=args.gen,
                            long_frac=args.long_frac)
    sub = f", {args.submodels} submodels ({args.router} routing, " \
          f"{args.ensemble_frac:.0%} ensemble)" if bank else ""
    print(f"serving {args.requests} requests ({args.stream} stream, "
          f"{args.slots} slots, {args.pages}x{args.page_size}-token pages, "
          f"budget {ecfg.token_budget} tok/tick, policy={args.policy}{sub})")

    t0 = time.monotonic()
    max_running = 0
    expected = 0
    next_stats = args.stats_every

    def stats_line() -> str:
        """One compact periodic line off the telemetry snapshot."""
        m = engine.metrics()
        c, tick = m["counters"], m["tick"]["tick_s"]
        wall = max(time.monotonic() - t0, 1e-9)
        hr = m["derived"]["prefix_hit_rate"]
        return (f"  [tick {c['steps']}] "
                f"{c['generated_tokens'] / wall:6.1f} tok/s  "
                f"run {len(engine.sched.running)}/{args.slots}  "
                f"wait {len(engine.sched.waiting)}  "
                f"pool {m['pool']['utilization']:.0%}  "
                f"tick p50 {(tick['p50'] or 0) * 1e3:.1f}ms  "
                f"hit {'n/a' if hr is None else format(hr, '.0%')}  "
                f"preempt {m['derived']['preemptions']}")

    try:
        while pending or engine.sched.has_work():
            now = time.monotonic() - t0
            while pending and pending[0][0] <= now:
                at, prompt, gen = pending.pop(0)
                ens = args.combine if bank is not None \
                    and rng.uniform() < args.ensemble_frac else None
                if recorder is not None:
                    # record the RESOLVED ensemble decision so replay
                    # does not depend on this loop's RNG state
                    recorder.add(at, prompt, gen, ensemble=ens)
                try:
                    engine.submit(prompt, gen, arrival_time=at, ensemble=ens)
                except ValueError as e:
                    print(f"FATAL: infeasible request — {e}", file=sys.stderr)
                    sys.exit(2)
                expected += args.submodels if ens else 1
            if not engine.sched.has_work():
                time.sleep(min(0.005, max(0.0, pending[0][0] - now)))
                continue
            for req in engine.step(time.monotonic() - t0,
                                   tick_clock=lambda: time.monotonic() - t0):
                pre = f"  ({req.num_preemptions}x preempted)" \
                    if req.num_preemptions else ""
                tag = f"  sub {req.submodel_id}" if bank else ""
                if req.group is not None:
                    tag = f"  ens {req.group.id}/{req.group.combine}" \
                          f" sub {req.submodel_id}"
                print(f"  req {req.id:3d} done: prompt {req.prompt_len:3d} "
                      f"+{len(req.out_tokens):3d} tok  "
                      f"ttft {req.t_first_token - req.arrival_time:6.3f}s  "
                      f"latency {req.t_done - req.arrival_time:6.3f}s"
                      f"{tag}{pre}")
            max_running = max(max_running, len(engine.sched.running))
            if args.stats_every and engine.steps >= next_stats:
                print(stats_line())
                next_stats = engine.steps + args.stats_every
    except EngineOOM as e:
        print(f"FATAL: unservable request — {e}", file=sys.stderr)
        sys.exit(2)
    wall = time.monotonic() - t0
    if recorder is not None:
        n = recorder.save(args.record_trace)
        print(f"recorded {n} requests -> {args.record_trace}")

    expected = expected if bank else args.requests
    assert len(engine.sched.finished) == expected, \
        (len(engine.sched.finished), expected)
    # an ensemble group delivers ONE stream: count it once (its leader) in
    # user-facing latency/throughput; device throughput counts members
    done = engine.finished_streams()
    ttft = [r.t_first_token - r.arrival_time for r in done]
    lat = [r.t_done - r.arrival_time for r in done]
    total_new = sum(len(r.out_tokens) for r in done)
    dev_new = sum(len(r.out_tokens) for r in engine.sched.finished)
    dev = f" ({dev_new / max(wall, 1e-9):.1f} device tok/s incl. ensemble " \
          f"members)" if dev_new != total_new else ""
    print(f"\n{len(done)} requests ({expected} sequences) in {wall:.2f}s  "
          f"(max {max_running}/{args.slots} slots concurrent)")
    print(f"throughput: {total_new / max(wall, 1e-9):.1f} tok/s{dev} "
          f"({engine.steps} ticks, "
          f"{engine.generated_tokens / max(engine.steps, 1):.1f} tok/tick, "
          f"{engine.prefill_tokens} prefill tok)")
    print(f"TTFT    p50 {percentile(ttft, 50):.3f}s  "
          f"p99 {percentile(ttft, 99):.3f}s")
    print(f"latency p50 {percentile(lat, 50):.3f}s  "
          f"p99 {percentile(lat, 99):.3f}s")
    _tail_report(engine, args, bank, wall)
    _exit_sanitize(engine)


def _exit_sanitize(engine) -> None:
    """Sanitizer verdict last, after every report section: a replay that
    served every token can still have leaked pages on the way."""
    san = getattr(engine, "_sanitizer", None)
    if san is None:
        return
    print(san.render_report())
    if san.alerts:
        sys.exit(3)


def _tail_report(engine, args, bank, wall: float) -> None:
    """Exit-report sections shared by the live and replay drive loops:
    pool / prefix-cache / speculative / bank / SLO state, anomaly
    alerts, compile attribution, and the trace export."""
    print(f"page-pool peak utilization: {engine.peak_utilization:.0%}  "
          f"preemptions: {engine.preemptions}  "
          f"block-table rows synced/tick: "
          f"{engine.bt_rows_synced / max(engine.steps, 1):.2f}")
    if args.prefix_cache:
        hr = engine.prefix_hit_rate     # None when nothing was eligible
        print(f"prefix cache: hit rate "
              f"{'n/a' if hr is None else format(hr, '.0%')}  "
              f"prefill tok saved {engine.prefill_tok_saved}  "
              f"evictions {engine.cache_evictions}  "
              f"COW copies {engine.cow_page_copies}")
    if args.speculate:
        print(f"speculative: accept rate {engine.accept_rate:.0%}  "
              f"accepted tok/tick {engine.accepted_tok_per_tick:.2f}  "
              f"drafted {engine.spec_drafted}  "
              f"draft calls {engine.spec.draft_calls}  "
              f"(K={args.speculate}, circuit {engine.spec.draft.circuit}, "
              f"kept {engine.spec.draft.kept_frac:.0%})")
    if bank is not None:
        per = "  ".join(
            f"sub{g}: {engine.tokens_by_submodel.get(g, 0) / max(wall, 1e-9):6.1f} tok/s"
            f" (peak util {engine.peak_util_by_submodel.get(g, 0.0):.0%})"
            for g in range(args.submodels))
        print(f"co-batch ratio: {engine.cobatch_ratio:.0%}  {per}")
    if args.slo_class:
        for name, rep in engine.obs.slo.report().items():
            att = rep["attainment"]
            tt = rep["ttft_target_s"]
            lt = rep["latency_target_s"]
            print(f"SLO [{name}] attainment "
                  f"{'n/a' if att is None else format(att, '.0%')} "
                  f"({rep['met']}/{rep['finished']}; targets "
                  f"ttft {'-' if tt is None else f'{tt:g}s'} "
                  f"latency {'-' if lt is None else f'{lt:g}s'}; "
                  f"violations ttft {rep['ttft_violations']} "
                  f"latency {rep['latency_violations']})")
    prof = engine.obs.profiler
    if prof is not None and prof.compiles_post_warm:
        print(f"compiles: {prof.compiles_post_warm} post-warmup "
              f"(of {prof.compiles_total} observed) — late jit compiles "
              f"are a perf regression signal")
    mon = engine.obs.anomaly
    if mon is not None and mon.counts:
        print("alerts: " + "  ".join(f"{k} x{n}"
                                     for k, n in sorted(mon.counts.items())))
        for a in list(mon.alerts)[-5:]:
            print(f"  [{a.kind}] tick {a.tick} t={a.t:.2f}s: {a.message}")
    else:
        print("alerts: none")
    if args.trace_out:
        n = engine.obs.timeline.export(args.trace_out)
        print(f"trace: {n} events over {engine.obs.timeline.ticks} ticks "
              f"-> {args.trace_out} (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
