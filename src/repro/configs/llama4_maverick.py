"""Llama4-Maverick 400B (17B active) [hf:meta-llama/Llama-4 family;
unverified-tier]: MoE 128e top-1 every other layer, early-fusion multimodal
(vision frontend STUBBED as 576 prefix patch embeddings)."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    moe_period=2, moe_offset=1, num_experts=128, experts_per_tok=1,
    moe_d_ff=8192, rope_theta=5e5, tie_embeddings=False, num_patches=576,
    layer_pattern=(ATTN,),
))
