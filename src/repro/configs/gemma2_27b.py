"""Gemma2-27B [arXiv:2408.00118; hf-verified]: local+global alternating,
logit softcaps, post-sublayer norms, query_pre_attn_scalar=144."""
from repro.configs.base import ATTN, LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    layer_pattern=(LOCAL, ATTN), sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    query_scale=144.0 ** -0.5, rope_theta=1e4,
    post_sublayer_norm=True, act="gelu", tie_embeddings=True,
))
