"""Mamba2-2.7B [arXiv:2405.21060; unverified-tier]: attn-free SSD stack.
d_inner=5120, 80 SSD heads of dim 64, state 128, no FFN sublayer."""
from repro.configs.base import MAMBA, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1, head_dim=1,
    d_ff=0, vocab_size=50280,
    layer_pattern=(MAMBA,), use_rope=False,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True,
))
