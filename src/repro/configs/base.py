"""Config system: model / shape / horn / run configs and the arch registry.

Every assigned architecture registers a ``ModelConfig`` here via its own
``configs/<arch>.py`` module.  Shapes are the four assigned input-shape cells.
``RunConfig`` bundles everything a launcher needs (mesh, topology, remat, ...).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds used in superblock patterns.
# ---------------------------------------------------------------------------
ATTN = "attn"          # full (global) attention
LOCAL = "local"        # sliding-window attention
MAMBA = "mamba"        # Mamba2 SSD mixer


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact public configs; see configs/<id>.py)."""

    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention variants -------------------------------------------------
    qk_norm: bool = False            # qwen3: RMSNorm on q,k per head
    qkv_bias: bool = False           # qwen1.5
    attn_logit_softcap: Optional[float] = None    # gemma2: 50.0
    final_logit_softcap: Optional[float] = None   # gemma2: 30.0
    query_scale: Optional[float] = None           # gemma2: (d_model/heads)^-0.5
    sliding_window: int = 4096       # window for LOCAL layers
    use_rope: bool = True
    rope_theta: float = 1e6

    # --- stack structure -----------------------------------------------------
    # One superblock of the repeating layer pattern; num_layers = k*len(pattern)+r,
    # remainder layers take pattern[:r].  Homogeneous stacks use a 1-entry pattern.
    layer_pattern: Tuple[str, ...] = (ATTN,)
    # Every `moe_period`-th layer's FFN is MoE (offset `moe_offset`); 0 = no MoE.
    moe_period: int = 0
    moe_offset: int = 0

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0                # expert hidden size (defaults to d_ff)

    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- enc-dec / multimodal --------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30s of audio frames (stub frontend)
    num_patches: int = 0             # vlm: stub patch-embedding count per sample

    # --- positions -------------------------------------------------------------
    learned_pos: bool = False        # whisper: learned absolute positions
    max_pos: int = 0                 # size of the learned position table

    # --- misc -----------------------------------------------------------------
    mlp_gated: bool = True           # SwiGLU/GeGLU-style gated MLP
    act: str = "silu"                # silu | gelu | relu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # gemma-style extra norms around sublayers (post-norms)
    post_sublayer_norm: bool = False

    # ------------------------------------------------------------------
    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def pattern_repeats(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def pattern_remainder(self) -> int:
        return self.num_layers % len(self.layer_pattern)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer mixer kinds for the full stack."""
        full = self.layer_pattern * self.pattern_repeats
        return tuple(full) + self.layer_pattern[: self.pattern_remainder]

    def layer_is_moe(self, idx: int) -> bool:
        if self.moe_period <= 0:
            return False
        return idx % self.moe_period == self.moe_offset

    @property
    def has_attention(self) -> bool:
        return any(k in (ATTN, LOCAL) for k in self.layer_pattern)

    @property
    def pure_full_attention(self) -> bool:
        """True when every mixer is *global* attention (no window/SSM structure)."""
        return all(k == ATTN for k in self.layer_pattern)

    # Parameter count (embedding + stack), used for 6ND model-FLOPs.
    def param_count(self, active_only: bool = False) -> int:
        d, h, kv, hd, ff = (self.d_model, self.num_heads, self.num_kv_heads,
                            self.head_dim, self.d_ff)
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            n += d  # pre-norm
            if kind in (ATTN, LOCAL):
                n += d * h * hd + 2 * d * kv * hd + h * hd * d  # q,k,v,o
                if self.qkv_bias:
                    n += (h + 2 * kv) * hd
                if self.qk_norm:
                    n += 2 * hd
            elif kind == MAMBA:
                d_in = self.ssm_expand * d
                nh = d_in // self.ssm_head_dim
                proj_in = 2 * d_in + 2 * self.ssm_state + nh   # z,x,B,C,dt
                n += d * proj_in                                # in_proj
                n += self.ssm_conv_width * (d_in + 2 * self.ssm_state)
                n += 2 * nh + nh * 0 + d_in * d                 # A,D(+dt_bias), out_proj
                n += d_in                                       # gated norm
            if self.layer_is_moe(i):
                e = self.experts_per_tok if active_only else self.num_experts
                mult = 3 if self.mlp_gated else 2
                n += e * mult * d * self.moe_ff + self.num_experts * d  # experts + router
                n += d  # ffn pre-norm
            else:
                mult = 3 if self.mlp_gated else 2
                n += mult * d * ff
                n += d
        n += d  # final norm
        if self.is_encoder_decoder:
            # encoder stack (self-attn + mlp) + decoder cross-attn blocks
            enc = self.num_encoder_layers * (
                d * h * hd + 2 * d * kv * hd + h * hd * d
                + (3 if self.mlp_gated else 2) * d * ff + 2 * d)
            xattn = self.num_layers * (d * h * hd + 2 * d * kv * hd + h * hd * d + d)
            n += enc + xattn
        return n


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class HornConfig:
    """Horn's collective & parallel dropout (the paper's technique).

    ``num_groups`` worker groups each draw an independent structured sub-model
    (block-aligned neuron dropout) per step; updates are batch-averaged.
    """

    enabled: bool = True
    num_groups: int = 0              # 0 => one group per data-parallel shard
    keep_input: float = 0.8          # paper: input-layer keep rate
    keep_hidden: float = 0.5         # paper: hidden-layer keep rate
    block_size: int = 128            # TPU-lane-aligned neuron blocks (beyond-paper)
    mask_attention_heads: bool = False   # also drop whole attention heads
    seed_salt: int = 0x484F524E      # "HORN"


@dataclass(frozen=True)
class TopologyConfig:
    """Horn topology choice: how groups merge updates (paper §2)."""

    kind: str = "allreduce"          # allreduce | zero1 (sharded PS) | local_sgd (downpour)
    local_sgd_period: int = 1        # H: steps between group merges (kind=local_sgd)
    grad_compression: str = "none"   # none | int8 (error feedback)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    horn: HornConfig = field(default_factory=HornConfig)
    topology: TopologyConfig = field(default_factory=TopologyConfig)
    optimizer: str = "sgdm"          # sgdm (paper) | adamw
    learning_rate: float = 0.3
    momentum: float = 0.98
    weight_decay: float = 0.0
    remat: str = "block"             # none | block (remat each scanned superblock)
    microbatches: int = 1            # gradient accumulation steps
    multi_pod: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_model_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # Import all config modules exactly once (registration side effect).
    import importlib
    for mod in (
        "qwen3_1p7b", "qwen1p5_4b", "gemma2_27b", "gemma3_4b", "mamba2_2p7b",
        "llava_next_34b", "jamba_1p5_large", "whisper_base", "phi3p5_moe",
        "llama4_maverick", "horn_mnist",
    ):
        importlib.import_module(f"repro.configs.{mod}")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (tiny dims, same structure)."""
    pattern = cfg.layer_pattern
    # keep at least one full superblock (so every mixer kind is exercised)
    num_layers = len(pattern) * max(1, min(2, cfg.pattern_repeats))
    base = dict(
        name=cfg.name + "-reduced",
        family=cfg.family,
        num_layers=num_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        qk_norm=cfg.qk_norm,
        qkv_bias=cfg.qkv_bias,
        attn_logit_softcap=cfg.attn_logit_softcap,
        final_logit_softcap=cfg.final_logit_softcap,
        sliding_window=16,
        use_rope=cfg.use_rope,
        layer_pattern=pattern,
        moe_period=cfg.moe_period,
        moe_offset=cfg.moe_offset,
        num_experts=min(cfg.num_experts, 4),
        experts_per_tok=min(cfg.experts_per_tok, 2),
        moe_d_ff=128 if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_expand=cfg.ssm_expand,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        ssm_conv_width=cfg.ssm_conv_width,
        is_encoder_decoder=cfg.is_encoder_decoder,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        encoder_seq=16,
        num_patches=min(cfg.num_patches, 8),
        mlp_gated=cfg.mlp_gated,
        act=cfg.act,
        norm=cfg.norm,
        tie_embeddings=cfg.tie_embeddings,
        post_sublayer_norm=cfg.post_sublayer_norm,
    )
    base.update(overrides)
    return ModelConfig(**base)
