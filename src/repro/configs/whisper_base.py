"""Whisper-base [arXiv:2212.04356; unverified-tier]: enc-dec, conv/audio
frontend STUBBED (input_specs provides 1536 precomputed frame embeddings).
LayerNorm, GELU, non-gated MLP, learned positions, no RoPE."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    is_encoder_decoder=True, num_encoder_layers=6, encoder_seq=1536,
    use_rope=False, learned_pos=True, max_pos=32768,
    norm="layernorm", act="gelu", mlp_gated=False, tie_embeddings=True,
    layer_pattern=(ATTN,),
))
