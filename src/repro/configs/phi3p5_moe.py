"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct;
hf-verified]: 16 experts top-2 on every layer."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    moe_period=1, moe_offset=0, num_experts=16, experts_per_tok=2,
    moe_d_ff=6400, rope_theta=1e4, tie_embeddings=False,
    layer_pattern=(ATTN,),
))
