"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf-verified]: Mamba+attn 1:7
interleave (attn at offset 4 of each 8-layer block), MoE 16e top-2 on every
other layer.  SSD formulation used for the mamba mixers (DESIGN.md §2)."""
from repro.configs.base import ATTN, MAMBA, ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    layer_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    use_rope=False,
    moe_period=2, moe_offset=1, num_experts=16, experts_per_tok=2,
    moe_d_ff=24576,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=False,
))
