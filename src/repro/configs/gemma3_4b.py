"""Gemma3-4B [hf:google/gemma-3-1b-pt family; unverified-tier]: 5:1
local:global, qk-norm, 128k context, dual rope bases (10k local / 1M global)."""
from repro.configs.base import ATTN, LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    layer_pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, ATTN),
    sliding_window=1024, qk_norm=True,
    query_scale=256.0 ** -0.5, rope_theta=1e6,
    post_sublayer_norm=True, act="gelu", tie_embeddings=True,
))
