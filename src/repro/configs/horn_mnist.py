"""The paper's own MNIST MLP (784 -> 512 -> 512 -> 10, ReLU, DropoutNeuron;
paper §3).  Built through the neuron-centric API; registered here so
``--arch horn-mnist`` selects the paper-faithful experiment."""
from repro.configs.base import ATTN, ModelConfig, register
from repro.core.neuron_centric import paper_mnist_network

CONFIG = register(ModelConfig(
    name="horn-mnist", family="mlp",
    num_layers=2, d_model=512, num_heads=1, num_kv_heads=1, head_dim=1,
    d_ff=512, vocab_size=10, use_rope=False, tie_embeddings=True,
    layer_pattern=(ATTN,),
))

def network(hidden: int = 512, depth: int = 2):
    return paper_mnist_network(hidden=hidden, depth=depth)
