"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6 family; unverified-tier]:
Yi-34B-ish backbone; anyres vision frontend STUBBED as 576 patch embeddings
prefixed to the text sequence (input_specs provides them precomputed)."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    rope_theta=5e6, tie_embeddings=False, num_patches=576,
    layer_pattern=(ATTN,),
))
