"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family; hf-verified]: QKV bias, MHA."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=151936,
    qkv_bias=True, rope_theta=5e6, tie_embeddings=False,
    layer_pattern=(ATTN,),
))
