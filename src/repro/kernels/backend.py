"""Kernel backend switch.

  * "pallas"    — compiled pallas_call, TPU target (production).
  * "interpret" — pallas_call(interpret=True): the kernel body executes in
                  Python on CPU; used by correctness tests in this container.
  * "ref"       — pure-jnp oracle (ref.py); used by the 512-device dry-run
                  (Pallas cannot lower to the CPU backend) and as the
                  allclose reference.
"""
from __future__ import annotations

import os

import jax

_BACKEND = None


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    try:
        if jax.devices()[0].platform == "tpu":
            return "pallas"
    except Exception:
        pass
    return "ref"


def get_backend() -> str:
    global _BACKEND
    if _BACKEND is None:
        _BACKEND = default_backend()
    return _BACKEND


def set_backend(name: str) -> None:
    assert name in ("pallas", "interpret", "ref"), name
    global _BACKEND
    _BACKEND = name


def interpret_mode() -> bool:
    return get_backend() == "interpret"
