"""Pure-jnp oracle for the flash attention kernel: naive full-scores
attention with identical masking/softcap semantics (small shapes only)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

f32 = jnp.float32


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None):
    """q: [B, H, Sq, D]; k, v: [B, KH, Skv, D] (H = KH * G). -> [B, H, Sq, D]"""
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Sq, D).astype(f32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(f32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.zeros((Sq, Skv), f32)
    if causal:
        mask = jnp.where(ki > qi, -1e30, mask)
    if window is not None:
        mask = jnp.where(ki <= qi - window, -1e30, mask)
    s = s + mask
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(f32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
