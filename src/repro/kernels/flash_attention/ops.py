"""Backend-switched flash attention wrapper ([B,H,S,D] layout)."""
from __future__ import annotations

from typing import Optional

from repro.kernels.backend import get_backend
from repro.kernels.flash_attention.kernel import flash_attention as _pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, **kw):
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        return attention_ref(q, k, v, scale=scale, causal=causal,
                             window=window, softcap=softcap)
    return _pallas(q, k, v, scale=scale, causal=causal, window=window,
                   softcap=softcap, interpret=backend == "interpret", **kw)
