"""Flash attention Pallas kernel (TPU): blocked online softmax in VMEM.

Grid: (B, H, Sq/bq, Skv/bk) — kv innermost (sequential, the only
``arbitrary`` dimension: the online-softmax carry lives across its steps;
batch/head/q-block are declared ``parallel`` so the Mosaic compiler may
split them across TPU megacore); the running
(max, sum, acc) live in VMEM scratch, so per-step HBM traffic is just the
Q/K/V tiles + final O tile instead of the [Sq, Skv] score matrix the ref path
streams through HBM (the dominant memory term of the dry-run baselines).

GQA is handled in the K/V index_map (q head h reads kv head h // G) — no
materialized repeat.  Causal + sliding-window masking is applied per-block
with iota; *fully* masked kv blocks are skipped via ``pl.when`` on block
indices, so local-attention layers do O(S * window) work, not O(S^2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], bq: int, bk: int, n_k: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level skip: causal => skip blocks entirely above the diagonal;
    # window => skip blocks entirely left of the window
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(f32)               # [bq, D]
        k = k_ref[0, 0].astype(f32)               # [bk, D]
        v = v_ref[0, 0].astype(f32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.zeros((bq, bk), f32)
        if causal:
            mask = jnp.where(kpos > qpos, NEG_INF, mask)
        if window is not None:
            mask = jnp.where(kpos <= qpos - window, NEG_INF, mask)
        s = s + mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """q: [B, H, Sq, D]; k, v: [B, KH, Skv, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    while Sq % bq:
        bq //= 2
    while Skv % bk:
        bk //= 2
    n_k = Skv // bk
    grid = (B, H, Sq // bq, n_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), f32),
                        pltpu.VMEM((bq, 1), f32),
                        pltpu.VMEM((bq, 1), f32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
