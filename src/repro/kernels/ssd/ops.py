"""Backend-switched SSD chunk-scan wrapper."""
from __future__ import annotations

from repro.kernels.backend import get_backend
from repro.kernels.ssd.kernel import ssd_chunk_scan as _pallas
from repro.kernels.ssd.ref import ssd_ref


def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, **kw):
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        y, _ = ssd_ref(x, dt, A, Bm, Cm)
        return y
    return _pallas(x, dt, A, Bm, Cm, chunk=chunk,
                   interpret=backend == "interpret", **kw)
