"""Pure-jnp oracle for the SSD chunk kernel: sequential (non-chunked)
state-space recurrence — the ground-truth semantics of Mamba2's SSD layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def ssd_ref(x, dt, A, Bm, Cm):
    """Token-by-token linear recurrence (exact, O(S) sequential).

    x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm, Cm: [B,S,N] -> y [B,S,H,P].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                    # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(dtt * A)[..., None, None]            # [B,H,1,1]
        upd = (dtt[..., None, None] * xt[..., None]
               * Bt[:, None, None, :])                       # [B,H,P,N]
        state = state * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    state0 = jnp.zeros((Bsz, H, P, N), f32)
    xs = (x.transpose(1, 0, 2, 3).astype(f32),
          dt.transpose(1, 0, 2).astype(f32),
          Bm.transpose(1, 0, 2).astype(f32),
          Cm.transpose(1, 0, 2).astype(f32))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), state
