"""Mamba2 SSD chunk-scan Pallas kernel.

Grid: (B, H, S/Q) — the chunk dim innermost/sequential; the inter-chunk
[P, N] state lives in VMEM scratch across grid steps (TPU guarantees
sequential iteration of the trailing grid dim), so the recurrence never
round-trips HBM.  Inside a chunk the dual quadratic form runs on the MXU:
CB^T ([Q,Q]), its decay/dt weighting, and three [Q,*] matmuls.

This is the TPU adaptation of mamba2's Triton kernel: same chunking math,
but the state-carry uses the sequential-grid + VMEM-scratch idiom instead of
a persistent CUDA block, and tile sizes follow (8,128)/MXU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            Q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(f32)               # [Q, P]
    dt = dt_ref[0, :, 0].astype(f32)             # [Q]
    A = a_ref[0]                                  # scalar (this head)
    Bm = b_ref[0].astype(f32)                     # [Q, N]
    Cm = c_ref[0].astype(f32)                     # [Q, N]

    dA = dt * A                                   # [Q], negative
    cum = jnp.cumsum(dA)                          # [Q]
    # intra-chunk dual form
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)       # [Q, Q]
    seg = cum[:, None] - cum[None, :]             # [Q, Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    qj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    T = jnp.where(qi >= qj, CB * jnp.exp(seg) * dt[None, :], 0.0)
    y = jax.lax.dot_general(T, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)        # [Q, P]
    # carried-state contribution: C_i . state * exp(cum_i)
    state = state_ref[...]                        # [P, N]
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)    # [Q, P]
    y = y + y_off * jnp.exp(cum)[:, None]
    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    # state update: state * exp(cum[-1]) + x^T @ (w[:,None] * B)
    w = jnp.exp(cum[-1] - cum) * dt               # [Q]
    state_new = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x, Bm * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=f32)               # [P, N]
    state_ref[...] = state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
                   interpret: bool = False):
    """x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm, Cm: [B,S,N] -> y [B,S,H,P] f32."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    n_chunks = S // Q

    grid = (B, H, n_chunks)
    kernel = functools.partial(_kernel, Q=Q, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), f32),
        scratch_shapes=[pltpu.VMEM((P, N), f32)],
        # the recurrent state carried in VMEM scratch across chunk steps
        # makes the chunk axis sequential; (batch, head) split across
        # megacore like the attention kernels
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A.astype(f32), Bm, Cm)
