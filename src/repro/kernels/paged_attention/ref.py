"""Pure-jnp oracles for the paged-attention kernels.

Gathers the K/V pages named by each sequence's block table into a contiguous
[B, maxp * psize, KH, D] view and runs a masked softmax — the same math the
Pallas kernels perform page-by-page in VMEM.  Two entry points:

  paged_attention_ref        one query token per sequence (decode)
  paged_chunk_attention_ref  a C-token chunk per sequence (chunked prefill /
                             the unified serving step); each token attends to
                             prior context plus the causal prefix of its own
                             chunk, all read back from the page pool

Used on CPU (where Pallas cannot lower) and as the allclose reference in
tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

f32 = jnp.float32
NEG_INF = -1e30


def dequantize_pages(pages, scale):
    """int8 pool [P, psize, KH, D] + per-(page, kv-head) scale [P, KH] ->
    f32 pool (the pure-jnp mirror of the kernel's in-register dequant)."""
    if scale is None:
        return pages
    return pages.astype(f32) * scale[:, None, :, None]


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale: float, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        k_scale=None, v_scale=None):
    """Single-token decode attention over a block-paged KV pool.

    q:            [B, H, D]   one query token per sequence
    k/v_pages:    [P, psize, KH, D]  shared page pool (page 0 = null page)
    block_tables: [B, maxp] int32    page ids per sequence, 0-padded
    lengths:      [B] int32          valid KV tokens per sequence (incl. the
                                     token just written at position len-1)
    k/v_scale:    [P, KH] f32, optional — int8-pool mode (pages are int8,
                                     dequantized before the gather)
    Returns [B, H, D].
    """
    k_pages = dequantize_pages(k_pages, k_scale)
    v_pages = dequantize_pages(v_pages, v_scale)
    B, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    S = maxp * psize

    k = k_pages[block_tables].reshape(B, S, KH, D).astype(f32)
    v = v_pages[block_tables].reshape(B, S, KH, D).astype(f32)
    qg = q.reshape(B, KH, G, D).astype(f32)

    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kp = jnp.arange(S)[None, :]
    mask = jnp.where(kp >= lengths[:, None], NEG_INF, 0.0)
    if window is not None:
        qpos = (lengths - 1)[:, None]
        mask = jnp.where(kp <= qpos - window, NEG_INF, mask)
    s = s + mask[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    # empty slots (length 0): softmax's shift-invariance would turn the
    # all-masked row into a uniform average of garbage — emit zeros like
    # the kernel (whose l accumulator stays 0) instead
    out = jnp.where(lengths[:, None, None, None] > 0, out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)


def paged_chunk_attention_ref(q, k_pages, v_pages, block_tables, starts,
                              chunk_lens, *, scale: float,
                              window: Optional[int] = None,
                              softcap: Optional[float] = None,
                              k_scale=None, v_scale=None, logit_index=None):
    """Chunk-append attention over a block-paged KV pool.

    q:            [B, C, H, D]  a chunk of C tokens per sequence, right-padded
                  (token j of sequence b sits at absolute position
                  ``starts[b] + j``; rows with j >= chunk_lens[b] are padding)
    k/v_pages:    [P, psize, KH, D]  shared page pool.  The chunk's own K/V
                  must already be written (append-then-attend)
    block_tables: [B, maxp] int32    page ids per sequence, 0-padded
    starts:       [B] int32          KV tokens in pages *before* this chunk
    chunk_lens:   [B] int32          valid tokens in this chunk (0 = idle slot)
    k/v_scale:    [P, KH] f32, optional — int8-pool mode
    logit_index:  [B, S] int32, optional — additionally return the S
                  selected chunk rows per slot (the kernel's fused verify
                  window): (out [B, C, H, D], out_win [B, S, H, D])
    Returns [B, C, H, D]; padding rows (and fully-idle slots) emit zeros.

    With C == 1 and chunk_lens == 1 this is exactly ``paged_attention_ref``
    at ``lengths = starts + 1`` — the decode special case.
    """
    k_pages = dequantize_pages(k_pages, k_scale)
    v_pages = dequantize_pages(v_pages, v_scale)
    B, C, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    S = maxp * psize

    k = k_pages[block_tables].reshape(B, S, KH, D).astype(f32)
    v = v_pages[block_tables].reshape(B, S, KH, D).astype(f32)
    qg = q.reshape(B, C, KH, G, D).astype(f32)

    s = jnp.einsum("bchgd,bshd->bhgcs", qg, k) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kp = jnp.arange(S)[None, None, :]                       # [1, 1, S]
    qpos = starts[:, None] + jnp.arange(C)[None, :]         # [B, C]
    lengths = starts + chunk_lens
    mask = jnp.where(kp >= lengths[:, None, None], NEG_INF, 0.0)
    mask = jnp.where(kp > qpos[..., None], NEG_INF, mask)   # causal own-chunk
    if window is not None:
        mask = jnp.where(kp <= qpos[..., None] - window, NEG_INF, mask)
    s = s + mask[:, None, None]                             # [B,KH,G,C,S]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgcs,bshd->bchgd", p, v)
    # padding rows (j >= chunk_len) still attend to the valid prior context
    # (their qpos lies past it), producing well-defined but meaningless
    # output; zero them like the kernel, which masks them at emit time
    valid = jnp.arange(C)[None, :] < chunk_lens[:, None]    # [B, C]
    out = jnp.where(valid[:, :, None, None, None], out, 0.0)
    out = out.reshape(B, C, H, D).astype(q.dtype)
    if logit_index is not None:
        win = jnp.take_along_axis(
            out, logit_index[:, :, None, None].astype(jnp.int32), axis=1)
        return out, win
    return out
