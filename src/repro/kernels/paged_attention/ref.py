"""Pure-jnp oracle for the paged-attention decode kernel.

Gathers the K/V pages named by each sequence's block table into a contiguous
[B, maxp * psize, KH, D] view and runs a masked single-token softmax — the
same math the Pallas kernel performs page-by-page in VMEM.  Used on CPU
(where Pallas cannot lower) and as the allclose reference in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

f32 = jnp.float32
NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale: float, window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """Single-token decode attention over a block-paged KV pool.

    q:            [B, H, D]   one query token per sequence
    k/v_pages:    [P, psize, KH, D]  shared page pool (page 0 = null page)
    block_tables: [B, maxp] int32    page ids per sequence, 0-padded
    lengths:      [B] int32          valid KV tokens per sequence (incl. the
                                     token just written at position len-1)
    Returns [B, H, D].
    """
    B, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    S = maxp * psize

    k = k_pages[block_tables].reshape(B, S, KH, D).astype(f32)
    v = v_pages[block_tables].reshape(B, S, KH, D).astype(f32)
    qg = q.reshape(B, KH, G, D).astype(f32)

    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kp = jnp.arange(S)[None, :]
    mask = jnp.where(kp >= lengths[:, None], NEG_INF, 0.0)
    if window is not None:
        qpos = (lengths - 1)[:, None]
        mask = jnp.where(kp <= qpos - window, NEG_INF, mask)
    s = s + mask[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    # empty slots (length 0): softmax's shift-invariance would turn the
    # all-masked row into a uniform average of garbage — emit zeros like
    # the kernel (whose l accumulator stays 0) instead
    out = jnp.where(lengths[:, None, None, None] > 0, out, 0.0)
    return out.reshape(B, H, D).astype(q.dtype)
