"""Paged-attention Pallas kernels (TPU): block-table K/V gather in VMEM.

Two kernels share one structure:

  paged_attention        one query token per sequence (decode)
  paged_chunk_attention  a C-token chunk per sequence — the unified serving
                         step's workhorse: decode slots ride as C == 1
                         chunks, admitting prompts as wider chunks, each
                         token attending to prior pages plus the causal
                         prefix of its own chunk (already appended to the
                         pool).  C == 1 reproduces paged_attention
                         bit-for-bit.

Per-sequence KV is addressed through a block table (the vLLM technique: KV
lives in a shared pool of fixed-size pages, so sequences of wildly different
lengths pack the HBM densely and join/leave a batch without reshuffling).

Grid: (B, KH, ceil(maxp / pages_per_step)) — pages innermost (sequential).
``dimension_semantics`` marks the (slot, kv-head) dimensions ``parallel`` so
TPU megacore splits the work across cores; only the page axis stays
``arbitrary`` (it carries the online-softmax (max, sum, acc) state in VMEM
scratch).  The block table and the per-sequence lengths ride in as
*scalar-prefetch* operands (``pltpu.PrefetchScalarGridSpec``) so the K/V
``index_map`` can resolve ``block_tables[b, page]`` before the DMA is
issued: the gather costs zero extra HBM traffic versus a contiguous cache.

``pages_per_step`` widens each grid step to ``pps`` whole pages: the grid's
innermost extent collapses by that factor and every step carries ``pps``
independently-indexed K and V blocks, so Pallas double-buffers the next
step's page DMAs against the current step's compute (gathered pages are not
contiguous in the pool, hence one BlockSpec *per page offset* rather than
one wider block).  ``pages_per_step=1`` reproduces the single-page kernel
bit-for-bit.

Dead grid steps (pages past ``ceil(len / psize)``) are clamped to the null
page 0 *in the index map* — stale or garbage block-table entries past a
sequence's length never reach the DMA engine (previously they triggered
real gathers of arbitrary pool pages, masked only at compute time), and the
compute is skipped via ``pl.when``.

int8 paged KV: pass ``k_scale``/``v_scale`` ([P, KH] f32, one symmetric
scale per (page, kv-head) — see ``optim/compression.quantize_int8`` with
``axis=(1, 3)``) and int8 pools; each gathered page is dequantized
in-register right after the DMA, so the HBM traffic per page is ~half of
bf16 and ~quarter of f32.

Fused verify windows: ``paged_chunk_attention(..., logit_index=[B, S])``
additionally emits the S selected chunk rows per slot as a second,
window-compacted output — gathered in the kernel epilogue while the chunk
output is still in VMEM, so speculative verify stops paying a separate
device-wide gather pass over the full-width output.

GQA: the grid iterates kv heads; each step processes the whole [G, D] group
of query heads that share the kv head — no materialized K/V repeat.
Sequences with ``length == 0`` (empty decode slots) emit zeros.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -1e30

# The pool reserves page 0 as the null page: dead grid steps, vacated
# block-table slots, and masked appends all route there.  The serving
# layer (block_table/kv_cache/ops) shares this constant — hornshape
# checks the index-map clamp against it symbolically.
NULL_PAGE = 0

# (slot, kv-head) are embarrassingly parallel — megacore may split them;
# the page axis is sequential (online-softmax carry in VMEM scratch)
DIM_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _kv_page_specs(*, pps: int, psize: int, maxp: int, D: int, length_of,
                   quantized: bool):
    """One (1, psize, 1, D) K/V BlockSpec per page offset j of a grid step,
    plus (1, 1) per-page scale specs in int8 mode.  Dead pages (past the
    sequence's live length) are clamped to the null page 0 in the index map
    itself, so garbage block-table entries are never dereferenced and no
    DMA bandwidth is spent on them."""
    def page_of(b, p, j, refs):
        bt = refs[0]
        pg = p * pps + j
        live = pg * psize < length_of(b, refs)
        return jnp.where(live, bt[b, jnp.minimum(pg, maxp - 1)], NULL_PAGE)

    def kv_map(j):
        return lambda b, h, p, *refs: (page_of(b, p, j, refs), 0, h, 0)

    def sc_map(j):
        return lambda b, h, p, *refs: (page_of(b, p, j, refs), h)

    kv = [pl.BlockSpec((1, psize, 1, D), kv_map(j)) for j in range(pps)]
    sc = [pl.BlockSpec((1, 1), sc_map(j)) for j in range(pps)] \
        if quantized else []
    return kv, sc


def _split_kv_refs(rest, *, pps: int, quantized: bool):
    """Kernel ref layout: k_0..k_{pps-1}, v_0.., [ksc_0.., vsc_0..], rest."""
    k_refs, v_refs = rest[:pps], rest[pps:2 * pps]
    base = 2 * pps
    ks_refs = vs_refs = None
    if quantized:
        ks_refs, vs_refs = rest[base:base + pps], rest[base + pps:base + 2 * pps]
        base += 2 * pps
    return k_refs, v_refs, ks_refs, vs_refs, rest[base:]


def _kernel(bt_ref, len_ref, q_ref, *rest, scale: float,
            window: Optional[int], softcap: Optional[float], psize: int,
            grid_p: int, pps: int, quantized: bool):
    k_refs, v_refs, ks_refs, vs_refs, tail = _split_kv_refs(
        rest, pps=pps, quantized=quantized)
    o_ref, acc_ref, m_ref, l_ref = tail
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    for j in range(pps):
        pg = p * pps + j
        live = pg * psize < length

        @pl.when(live)
        def _page(j=j, pg=pg):
            q = q_ref[0, 0].astype(f32)                 # [G, D]
            k = k_refs[j][0, :, 0].astype(f32)          # [psize, D]
            v = v_refs[j][0, :, 0].astype(f32)
            if quantized:                               # in-register dequant
                k = k * ks_refs[j][0, 0]
                v = v * vs_refs[j][0, 0]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=f32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            kpos = pg * psize + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)                  # [G, psize]
            mask = jnp.where(kpos >= length, NEG_INF, 0.0)
            if window is not None:
                mask = jnp.where(kpos <= length - 1 - window, NEG_INF, mask)
            s = s + mask
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            prob = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + jnp.sum(prob, -1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                prob, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
            m_ref[...] = m_new

    @pl.when(p == grid_p - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _chunk_kernel(bt_ref, start_ref, clen_ref, *rest, scale: float,
                  window: Optional[int], softcap: Optional[float],
                  psize: int, grid_p: int, pps: int, C: int, G: int,
                  quantized: bool, S_w: int):
    """Chunk-append variant: q is [C * G, D] per (sequence, kv-head) — C
    chunk tokens x G grouped query heads.  Row r holds chunk token r // G at
    absolute position ``start + r // G``; the mask adds a causal constraint
    against the token's own chunk prefix on top of the decode kernel's
    length mask.  Padding rows (token index >= chunk_len) are zeroed at
    emit.  With C == 1 every op matches ``_kernel`` bit-for-bit.

    ``S_w > 0``: a ``logit_index`` [B, S_w] scalar-prefetch operand follows
    the block table, and the epilogue additionally writes the S_w selected
    chunk rows into a window-compacted second output (the fused speculative
    verify window — no separate full-width gather pass)."""
    if S_w:
        widx_ref, rest = rest[0], rest[1:]
    q_ref, rest = rest[0], rest[1:]
    k_refs, v_refs, ks_refs, vs_refs, tail = _split_kv_refs(
        rest, pps=pps, quantized=quantized)
    if S_w:
        o_ref, ow_ref, acc_ref, m_ref, l_ref = tail
    else:
        o_ref, acc_ref, m_ref, l_ref = tail
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    clen = clen_ref[b]
    length = start + clen
    for j in range(pps):
        pg = p * pps + j
        live = pg * psize < length

        @pl.when(live)
        def _page(j=j, pg=pg):
            q = q_ref[0, 0].astype(f32)                 # [C * G, D]
            k = k_refs[j][0, :, 0].astype(f32)          # [psize, D]
            v = v_refs[j][0, :, 0].astype(f32)
            if quantized:                               # in-register dequant
                k = k * ks_refs[j][0, 0]
                v = v * vs_refs[j][0, 0]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=f32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            kpos = pg * psize + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)                  # [C*G, psize]
            qpos = start + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0) // G             # row r -> token r // G
            mask = jnp.where(kpos >= length, NEG_INF, 0.0)
            mask = jnp.where(kpos > qpos, NEG_INF, mask)   # causal own-chunk
            if window is not None:
                mask = jnp.where(kpos <= qpos - window, NEG_INF, mask)
            s = s + mask
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            prob = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + jnp.sum(prob, -1, keepdims=True)
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                prob, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
            m_ref[...] = m_new

    @pl.when(p == grid_p - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        tok = jax.lax.broadcasted_iota(jnp.int32, out.shape, 0) // G
        final = jnp.where(tok < clen, out, 0.0).astype(o_ref.dtype)
        o_ref[0, 0] = final
        if S_w:
            # fused verify window: gather the S_w selected rows while the
            # chunk output sits in VMEM (row tok t -> q-head group t*G:+G)
            for sw in range(S_w):
                t = widx_ref[b, sw]
                ow_ref[0, 0, sw * G:(sw + 1) * G, :] = \
                    jax.lax.dynamic_slice_in_dim(final, t * G, G, axis=0)


def _check_quant(k_pages, k_scale, v_scale):
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    if k_scale is not None and k_pages.dtype != jnp.int8:
        raise ValueError(
            f"scales given but pages are {k_pages.dtype}, expected int8")
    return k_scale is not None


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret", "pages_per_step"))
def paged_chunk_attention(q, k_pages, v_pages, block_tables, starts,
                          chunk_lens, *, scale: float,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None,
                          interpret: bool = False,
                          pages_per_step: int = 1,
                          k_scale=None, v_scale=None, logit_index=None):
    """q: [B, C, H, D] right-padded chunks; k/v_pages: [P, psize, KH, D]
    (the chunk's own K/V already appended); block_tables: [B, maxp];
    starts/chunk_lens: [B] -> [B, C, H, D].  See paged_chunk_attention_ref
    for the contract; C == 1 reproduces ``paged_attention`` bit-for-bit.

    ``pages_per_step`` processes that many pages per grid step (double-
    buffered page DMAs); 1 reproduces the single-page kernel bit-for-bit.
    ``k_scale``/``v_scale`` ([P, KH] f32) enable the int8-pool mode.
    ``logit_index`` ([B, S] int32 chunk positions, each < chunk_len or 0)
    switches the return to ``(out [B, C, H, D], out_win [B, S, H, D])``
    with the window rows gathered in the kernel epilogue."""
    B, C, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    quantized = _check_quant(k_pages, k_scale, v_scale)
    pps = max(1, min(pages_per_step, maxp))
    grid_p = -(-maxp // pps)
    S_w = 0 if logit_index is None else logit_index.shape[1]
    # [B, KH, C*G, D]: chunk tokens x grouped query heads, flattened so the
    # kernel works on one 2-D block per (seq, kv head) like the decode kernel
    qg = q.reshape(B, C, KH, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, KH, C * G, D)

    kernel = functools.partial(
        _chunk_kernel, scale=scale, window=window, softcap=softcap,
        psize=psize, grid_p=grid_p, pps=pps, C=C, G=G, quantized=quantized,
        S_w=S_w)
    kv_specs, sc_specs = _kv_page_specs(
        pps=pps, psize=psize, maxp=maxp, D=D,
        length_of=lambda b, refs: refs[1][b] + refs[2][b], quantized=quantized)
    q_spec = pl.BlockSpec((1, 1, C * G, D), lambda b, h, p, *refs: (b, h, 0, 0))
    out_spec = pl.BlockSpec((1, 1, C * G, D),
                            lambda b, h, p, *refs: (b, h, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, KH, C * G, D), q.dtype)
    out_specs, out_shapes = out_spec, out_shape
    if S_w:
        out_specs = [out_spec,
                     pl.BlockSpec((1, 1, S_w * G, D),
                                  lambda b, h, p, *refs: (b, h, 0, 0))]
        out_shapes = [out_shape,
                      jax.ShapeDtypeStruct((B, KH, S_w * G, D), q.dtype)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 + (1 if S_w else 0),
        grid=(B, KH, grid_p),
        in_specs=[q_spec] + kv_specs + kv_specs + sc_specs + sc_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((C * G, D), f32),
                        pltpu.VMEM((C * G, 1), f32),
                        pltpu.VMEM((C * G, 1), f32)],
    )
    scalars = [block_tables.astype(jnp.int32), starts.astype(jnp.int32),
               chunk_lens.astype(jnp.int32)]
    if S_w:
        scalars.append(logit_index.astype(jnp.int32))
    args = scalars + [qg] + [k_pages] * pps + [v_pages] * pps
    if quantized:
        args += [k_scale] * pps + [v_scale] * pps
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=DIM_SEMANTICS),
        interpret=interpret,
    )(*args)

    def unflatten(o, n):
        return o.reshape(B, KH, n, G, D).transpose(0, 2, 1, 3, 4).reshape(
            B, n, H, D)

    if S_w:
        return unflatten(out[0], C), unflatten(out[1], S_w)
    return unflatten(out, C)


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret", "pages_per_step"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False, pages_per_step: int = 1,
                    k_scale=None, v_scale=None):
    """q: [B, H, D]; k/v_pages: [P, psize, KH, D]; block_tables: [B, maxp];
    lengths: [B] -> [B, H, D].  ``pages_per_step``/``k_scale``/``v_scale``
    as in ``paged_chunk_attention``."""
    B, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    quantized = _check_quant(k_pages, k_scale, v_scale)
    pps = max(1, min(pages_per_step, maxp))
    grid_p = -(-maxp // pps)
    qg = q.reshape(B, KH, G, D)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        psize=psize, grid_p=grid_p, pps=pps, quantized=quantized)
    kv_specs, sc_specs = _kv_page_specs(
        pps=pps, psize=psize, maxp=maxp, D=D,
        length_of=lambda b, refs: refs[1][b], quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, grid_p),
        in_specs=[pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, *refs: (b, h, 0, 0))]
        + kv_specs + kv_specs + sc_specs + sc_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, *refs: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, D), f32),
                        pltpu.VMEM((G, 1), f32),
                        pltpu.VMEM((G, 1), f32)],
    )
    args = [block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qg] \
        + [k_pages] * pps + [v_pages] * pps
    if quantized:
        args += [k_scale] * pps + [v_scale] * pps
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=DIM_SEMANTICS),
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, D)
