"""Paged-attention Pallas kernels (TPU): block-table K/V gather in VMEM.

Two kernels share one structure:

  paged_attention        one query token per sequence (decode)
  paged_chunk_attention  a C-token chunk per sequence — the unified serving
                         step's workhorse: decode slots ride as C == 1
                         chunks, admitting prompts as wider chunks, each
                         token attending to prior pages plus the causal
                         prefix of its own chunk (already appended to the
                         pool).  C == 1 reproduces paged_attention
                         bit-for-bit.

Per-sequence KV is addressed through a block table (the vLLM technique: KV
lives in a shared pool of fixed-size pages, so sequences of wildly different
lengths pack the HBM densely and join/leave a batch without reshuffling).

Grid: (B, KH, maxp) — pages innermost (sequential).  The block table and the
per-sequence lengths ride in as *scalar-prefetch* operands
(``pltpu.PrefetchScalarGridSpec``) so the K/V ``index_map`` can resolve
``block_tables[b, p]`` before the DMA is issued: the gather costs zero extra
HBM traffic versus a contiguous cache.  Running (max, sum, acc) live in VMEM
scratch across page iterations (online softmax, as in flash_attention).

GQA: the grid iterates kv heads; each step processes the whole [G, D] group
of query heads that share the kv head — no materialized K/V repeat.  Pages
past ``ceil(len / psize)`` are skipped via ``pl.when`` (no DMA is wasted on
them being masked; they still occupy grid steps, which is the price of a
static grid).  Sequences with ``length == 0`` (empty decode slots) emit
zeros.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32
NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: Optional[int],
            softcap: Optional[float], psize: int, n_pages: int):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    live = p * psize < length

    @pl.when(live)
    def _page():
        q = q_ref[0, 0].astype(f32)                     # [G, D]
        k = k_ref[0, :, 0].astype(f32)                  # [psize, D]
        v = v_ref[0, :, 0].astype(f32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = p * psize + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)                      # [G, psize]
        mask = jnp.where(kpos >= length, NEG_INF, 0.0)
        if window is not None:
            mask = jnp.where(kpos <= length - 1 - window, NEG_INF, mask)
        s = s + mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        prob = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            prob, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _chunk_kernel(bt_ref, start_ref, clen_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float,
                  window: Optional[int], softcap: Optional[float],
                  psize: int, n_pages: int, C: int, G: int):
    """Chunk-append variant: q is [C * G, D] per (sequence, kv-head) — C
    chunk tokens x G grouped query heads.  Row r holds chunk token r // G at
    absolute position ``start + r // G``; the mask adds a causal constraint
    against the token's own chunk prefix on top of the decode kernel's
    length mask.  Padding rows (token index >= chunk_len) are zeroed at
    emit.  With C == 1 every op matches ``_kernel`` bit-for-bit."""
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    clen = clen_ref[b]
    length = start + clen
    live = p * psize < length

    @pl.when(live)
    def _page():
        q = q_ref[0, 0].astype(f32)                     # [C * G, D]
        k = k_ref[0, :, 0].astype(f32)                  # [psize, D]
        v = v_ref[0, :, 0].astype(f32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = p * psize + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)                      # [C*G, psize]
        qpos = start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0) // G                 # row r -> token r // G
        mask = jnp.where(kpos >= length, NEG_INF, 0.0)
        mask = jnp.where(kpos > qpos, NEG_INF, mask)    # causal own-chunk
        if window is not None:
            mask = jnp.where(kpos <= qpos - window, NEG_INF, mask)
        s = s + mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        prob = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            prob, v, (((1,), (0,)), ((), ())), preferred_element_type=f32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        tok = jax.lax.broadcasted_iota(jnp.int32, out.shape, 0) // G
        o_ref[0, 0] = jnp.where(tok < clen, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret"))
def paged_chunk_attention(q, k_pages, v_pages, block_tables, starts,
                          chunk_lens, *, scale: float,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None,
                          interpret: bool = False):
    """q: [B, C, H, D] right-padded chunks; k/v_pages: [P, psize, KH, D]
    (the chunk's own K/V already appended); block_tables: [B, maxp];
    starts/chunk_lens: [B] -> [B, C, H, D].  See paged_chunk_attention_ref
    for the contract; C == 1 reproduces ``paged_attention`` bit-for-bit."""
    B, C, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    # [B, KH, C*G, D]: chunk tokens x grouped query heads, flattened so the
    # kernel works on one 2-D block per (seq, kv head) like the decode kernel
    qg = q.reshape(B, C, KH, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, KH, C * G, D)

    kernel = functools.partial(
        _chunk_kernel, scale=scale, window=window, softcap=softcap,
        psize=psize, n_pages=maxp, C=C, G=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KH, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, C * G, D),
                         lambda b, h, p, bt, st, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, psize, 1, D),
                         lambda b, h, p, bt, st, cl: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, psize, 1, D),
                         lambda b, h, p, bt, st, cl: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C * G, D),
                               lambda b, h, p, bt, st, cl: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((C * G, D), f32),
                        pltpu.VMEM((C * G, 1), f32),
                        pltpu.VMEM((C * G, 1), f32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, C * G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), starts.astype(jnp.int32),
      chunk_lens.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, KH, C, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, C, H, D)


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    interpret: bool = False):
    """q: [B, H, D]; k/v_pages: [P, psize, KH, D]; block_tables: [B, maxp];
    lengths: [B] -> [B, H, D]."""
    B, H, D = q.shape
    psize, KH = k_pages.shape[1], k_pages.shape[2]
    maxp = block_tables.shape[1]
    G = H // KH
    qg = q.reshape(B, KH, G, D)

    kernel = functools.partial(
        _kernel, scale=scale, window=window, softcap=softcap,
        psize=psize, n_pages=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, psize, 1, D),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, psize, 1, D),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, D), f32),
                        pltpu.VMEM((G, 1), f32),
                        pltpu.VMEM((G, 1), f32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, D)
