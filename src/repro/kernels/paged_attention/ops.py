"""Backend-switched paged attention (decode + chunk-append) and the paged
KV-pool scatter updates (f32/bf16 pools and the int8 + per-page-scale
quantized mode)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.backend import get_backend
from repro.kernels.paged_attention.kernel import NULL_PAGE
from repro.kernels.paged_attention.kernel import paged_attention as _pallas
from repro.kernels.paged_attention.kernel import \
    paged_chunk_attention as _pallas_chunk
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_chunk_attention_ref)
from repro.optim.compression import quantize_int8

f32 = jnp.float32

# Default pages-per-grid-step for the Pallas kernels (the engine sets this
# once at construction from EngineConfig.pages_per_step, before tracing its
# jitted steps; kernel-level callers can always pass pages_per_step=...
# explicitly).  1 reproduces the classic single-page kernel bit-for-bit.
_PAGES_PER_STEP = 1


def set_pages_per_step(n: int) -> None:
    """Set the process-wide default ``pages_per_step`` for the paged
    kernels.  A static tuning knob: it is read at trace time, so set it
    before the first call of any jitted step that should use it."""
    global _PAGES_PER_STEP
    if n < 1:
        raise ValueError(f"pages_per_step must be >= 1, got {n}")
    _PAGES_PER_STEP = int(n)


def get_pages_per_step() -> int:
    return _PAGES_PER_STEP


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    k_scale=None, v_scale=None, **kw):
    """Dispatch [B, H, D] paged decode attention to pallas / interpret / ref."""
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        kw.pop("pages_per_step", None)
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale, window=window, softcap=softcap,
                                   k_scale=k_scale, v_scale=v_scale)
    kw.setdefault("pages_per_step", _PAGES_PER_STEP)
    return _pallas(q, k_pages, v_pages, block_tables, lengths, scale=scale,
                   window=window, softcap=softcap, k_scale=k_scale,
                   v_scale=v_scale, interpret=backend == "interpret", **kw)


def paged_chunk_attention(q, k_pages, v_pages, block_tables, starts,
                          chunk_lens, *, scale: float,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None,
                          k_scale=None, v_scale=None, logit_index=None,
                          **kw):
    """Dispatch [B, C, H, D] chunk-append paged attention (the unified
    serving step: decode tokens are C == 1 chunks, prompt chunks are wider).
    ``logit_index`` [B, S] turns on the fused verify-window output (returns
    (out, out_win)); ``k_scale``/``v_scale`` select the int8-pool mode."""
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        kw.pop("pages_per_step", None)
        return paged_chunk_attention_ref(
            q, k_pages, v_pages, block_tables, starts, chunk_lens,
            scale=scale, window=window, softcap=softcap,
            k_scale=k_scale, v_scale=v_scale, logit_index=logit_index)
    kw.setdefault("pages_per_step", _PAGES_PER_STEP)
    return _pallas_chunk(q, k_pages, v_pages, block_tables, starts,
                         chunk_lens, scale=scale, window=window,
                         softcap=softcap, k_scale=k_scale, v_scale=v_scale,
                         logit_index=logit_index,
                         interpret=backend == "interpret", **kw)


def paged_pool_update(pool, new, block_tables, positions):
    """Write one token per sequence into its page at ``positions``.

    pool: [P, psize, KH, D]; new: [B, KH, D]; block_tables: [B, maxp];
    positions: [B] absolute write positions.  Empty slots must point at the
    reserved null page 0 (their garbage writes land there harmlessly).
    """
    psize = pool.shape[1]
    page = jnp.take_along_axis(
        block_tables, (positions // psize)[:, None], axis=1)[:, 0]
    slot = positions % psize
    return pool.at[page, slot].set(new.astype(pool.dtype))


def paged_pool_append(pool, new, block_tables, starts, chunk_lens):
    """Scatter each sequence's C-token chunk into its pages.

    pool: [P, psize, KH, D]; new: [B, C, KH, D]; block_tables: [B, maxp];
    starts: [B] absolute position of each chunk's first token; chunk_lens:
    [B] valid tokens per chunk.  Padding tokens (j >= chunk_len) are routed
    to the null page 0, so a partially-filled chunk never corrupts pages
    beyond the sequence's allocation.
    """
    B, C = new.shape[:2]
    psize, maxp = pool.shape[1], block_tables.shape[1]
    pos = starts[:, None] + jnp.arange(C)[None, :]              # [B, C]
    pidx = jnp.clip(pos // psize, 0, maxp - 1)
    page = jnp.take_along_axis(block_tables, pidx, axis=1)
    valid = jnp.arange(C)[None, :] < chunk_lens[:, None]
    page = jnp.where(valid, page, NULL_PAGE)
    slot = pos % psize
    return pool.at[page.reshape(-1), slot.reshape(-1)].set(
        new.reshape((B * C,) + new.shape[2:]).astype(pool.dtype))


def paged_pool_append_quant(pool, scale, new, block_tables, starts,
                            chunk_lens):
    """int8 variant of ``paged_pool_append``: quantize-on-append.

    pool: [P, psize, KH, D] int8; scale: [P, KH] f32 (one symmetric scale
    per (page, kv-head), ``optim/compression.quantize_int8`` semantics);
    new: [B, C, KH, D] fresh K or V in compute dtype.

    Only the pages the chunk touches are rewritten: they are gathered,
    dequantized, the new tokens spliced in at f32, and the whole page
    re-quantized with a fresh per-(page, head) scale — so a page's scale
    always reflects its current contents (appending a large-magnitude token
    re-ranges the page's older tokens too, which is what keeps the
    roundtrip error bound per page instead of drifting).  Padding tokens
    and out-of-table positions fall onto the null page 0 exactly like the
    unquantized path.  Returns (pool, scale).
    """
    P, psize, KH, D = pool.shape
    B, C = new.shape[:2]
    maxp = block_tables.shape[1]
    # pages a row's chunk can touch: the page holding ``start`` plus every
    # page the C tokens can spill into
    T = (C + psize - 1) // psize + 1
    p0 = starts // psize                                        # [B]
    prel = p0[:, None] + jnp.arange(T)[None, :]                 # [B, T]
    pvalid = prel < maxp
    pages = jnp.take_along_axis(block_tables,
                                jnp.clip(prel, 0, maxp - 1), axis=1)
    pages = jnp.where(pvalid, pages, NULL_PAGE)                 # [B, T]
    got = pool[pages].astype(f32) * scale[pages][:, :, None, :, None]
    # splice the chunk tokens into the gathered pages at f32
    pos = starts[:, None] + jnp.arange(C)[None, :]              # [B, C]
    valid = jnp.arange(C)[None, :] < chunk_lens[:, None]
    t = pos // psize - p0[:, None]                              # [B, C]
    t = jnp.where(valid & (t >= 0) & (t < T), t, T)             # T -> dropped
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    got = got.at[b_ix.reshape(-1), t.reshape(-1),
                 (pos % psize).reshape(-1)].set(
        new.reshape(B * C, KH, D).astype(f32), mode="drop")
    q, nsc = quantize_int8(got, axis=(2, 4))                    # [B,T,1,KH,1]
    pool = pool.at[pages.reshape(-1)].set(q.reshape(-1, psize, KH, D))
    scale = scale.at[pages.reshape(-1)].set(nsc.reshape(-1, KH))
    # writes routed to the null page (padding / dead rows) may have raced;
    # its contents are never read as live data, but keep its scale sane
    return pool, scale
