"""Backend-switched paged attention (decode + chunk-append) and the paged
KV-pool scatter updates."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.backend import get_backend
from repro.kernels.paged_attention.kernel import paged_attention as _pallas
from repro.kernels.paged_attention.kernel import \
    paged_chunk_attention as _pallas_chunk
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_chunk_attention_ref)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float, window: Optional[int] = None,
                    softcap: Optional[float] = None, **kw):
    """Dispatch [B, H, D] paged decode attention to pallas / interpret / ref."""
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale, window=window, softcap=softcap)
    return _pallas(q, k_pages, v_pages, block_tables, lengths, scale=scale,
                   window=window, softcap=softcap,
                   interpret=backend == "interpret", **kw)


def paged_chunk_attention(q, k_pages, v_pages, block_tables, starts,
                          chunk_lens, *, scale: float,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None, **kw):
    """Dispatch [B, C, H, D] chunk-append paged attention (the unified
    serving step: decode tokens are C == 1 chunks, prompt chunks are wider)."""
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        return paged_chunk_attention_ref(
            q, k_pages, v_pages, block_tables, starts, chunk_lens,
            scale=scale, window=window, softcap=softcap)
    return _pallas_chunk(q, k_pages, v_pages, block_tables, starts,
                         chunk_lens, scale=scale, window=window,
                         softcap=softcap,
                         interpret=backend == "interpret", **kw)


def paged_pool_update(pool, new, block_tables, positions):
    """Write one token per sequence into its page at ``positions``.

    pool: [P, psize, KH, D]; new: [B, KH, D]; block_tables: [B, maxp];
    positions: [B] absolute write positions.  Empty slots must point at the
    reserved null page 0 (their garbage writes land there harmlessly).
    """
    psize = pool.shape[1]
    page = jnp.take_along_axis(
        block_tables, (positions // psize)[:, None], axis=1)[:, 0]
    slot = positions % psize
    return pool.at[page, slot].set(new.astype(pool.dtype))


def paged_pool_append(pool, new, block_tables, starts, chunk_lens):
    """Scatter each sequence's C-token chunk into its pages.

    pool: [P, psize, KH, D]; new: [B, C, KH, D]; block_tables: [B, maxp];
    starts: [B] absolute position of each chunk's first token; chunk_lens:
    [B] valid tokens per chunk.  Padding tokens (j >= chunk_len) are routed
    to the null page 0, so a partially-filled chunk never corrupts pages
    beyond the sequence's allocation.
    """
    B, C = new.shape[:2]
    psize, maxp = pool.shape[1], block_tables.shape[1]
    pos = starts[:, None] + jnp.arange(C)[None, :]              # [B, C]
    pidx = jnp.clip(pos // psize, 0, maxp - 1)
    page = jnp.take_along_axis(block_tables, pidx, axis=1)
    valid = jnp.arange(C)[None, :] < chunk_lens[:, None]
    page = jnp.where(valid, page, 0)
    slot = pos % psize
    return pool.at[page.reshape(-1), slot.reshape(-1)].set(
        new.reshape((B * C,) + new.shape[2:]).astype(pool.dtype))
