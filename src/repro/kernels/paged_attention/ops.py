"""Backend-switched paged attention + the paged KV-pool scatter update."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.backend import get_backend
from repro.kernels.paged_attention.kernel import paged_attention as _pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale: float, window: Optional[int] = None,
                    softcap: Optional[float] = None, **kw):
    """Dispatch [B, H, D] paged decode attention to pallas / interpret / ref."""
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale, window=window, softcap=softcap)
    return _pallas(q, k_pages, v_pages, block_tables, lengths, scale=scale,
                   window=window, softcap=softcap,
                   interpret=backend == "interpret", **kw)


def paged_pool_update(pool, new, block_tables, positions):
    """Write one token per sequence into its page at ``positions``.

    pool: [P, psize, KH, D]; new: [B, KH, D]; block_tables: [B, maxp];
    positions: [B] absolute write positions.  Empty slots must point at the
    reserved null page 0 (their garbage writes land there harmlessly).
    """
    psize = pool.shape[1]
    page = jnp.take_along_axis(
        block_tables, (positions // psize)[:, None], axis=1)[:, 0]
    slot = positions % psize
    return pool.at[page, slot].set(new.astype(pool.dtype))
