"""Pure-jnp oracle for the block-sparse dropout matmul.

Semantics: ``y[g] = (x[g] @ w) * expand(mask[g])`` where ``mask[g]`` holds one
value in {0, 1/keep} per contiguous block of ``block_n`` output units — Horn's
irregular sub-model: group g's sub-model simply lacks the dropped neurons.
"""
from __future__ import annotations

import jax.numpy as jnp


def dropout_matmul_ref(x, w, mask_blocks, *, block_n: int):
    """x: [G, M, K]; w: [K, N]; mask_blocks: [G, N // block_n] in {0, 1/keep}.

    Returns [G, M, N] float32.
    """
    G, M, K = x.shape
    N = w.shape[1]
    y = jnp.einsum("gmk,kn->gmn", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    mask = jnp.repeat(mask_blocks.astype(jnp.float32), block_n, axis=-1)
    return y * mask[:, None, :]
