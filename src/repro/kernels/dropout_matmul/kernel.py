"""Block-sparse dropout matmul — the Horn hot-spot kernel.

The paper claims sub-model training "reduce[s] the size of model [and]
improve[s] the computing performance"; with naive masking the dropped units
still burn MXU cycles.  This kernel makes the claim real on TPU: the dropout
mask is drawn per 128-wide block of output units (core/submodel.py), the mask
value for the (group, n-block) lives in SMEM, and the whole K-loop of a
dropped output tile is *skipped* (``pl.when``), so FLOPs and VMEM traffic
scale with the kept fraction (~keep_rate at steady state).

Grid: (G, M/bm, N/bn, K/bk), K innermost (sequential accumulation in a VMEM
scratch accumulator, fp32; the only ``arbitrary`` dimension — G/M/N tiles
are independent and declared ``parallel`` for TPU megacore partitioning).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

f32 = jnp.float32


def _kernel(mask_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(3)
    mval = mask_ref[0, 0]                       # this (g, n-block)'s mask value

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mval != 0.0)                       # skip dropped blocks entirely
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=f32)

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] * mval).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def dropout_matmul(x, w, mask_blocks, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool = False):
    """x: [G, M, K]; w: [K, N]; mask_blocks: [G, N/block_n] -> [G, M, N] f32.

    ``block_n`` must equal the mask's neuron-block size (Horn block_size).
    """
    G, M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert mask_blocks.shape == (G, N // bn), mask_blocks.shape
    n_k = K // bk

    grid = (G, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda g, mi, ni, ki: (g, ni),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bm, bk), lambda g, mi, ni, ki: (g, mi, ki)),
            pl.BlockSpec((bk, bn), lambda g, mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, mi, ni, ki: (g, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), f32),
        scratch_shapes=[pltpu.VMEM((bm, bn), f32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(mask_blocks.astype(f32), x, w)
