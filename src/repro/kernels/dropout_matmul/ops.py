"""Backend-switched wrapper for the block-sparse dropout matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.backend import get_backend
from repro.kernels.dropout_matmul.kernel import dropout_matmul as _pallas
from repro.kernels.dropout_matmul.ref import dropout_matmul_ref


def dropout_matmul(x, w, mask_blocks, *, block_n: int = 128, **kw):
    """y[g] = (x[g] @ w) * expand(mask[g]); dropped blocks are skipped on TPU.

    x: [G, M, K]; w: [K, N]; mask_blocks: [G, N / block_n] in {0, 1/keep}.
    """
    backend = kw.pop("backend", None) or get_backend()
    if backend == "ref":
        return dropout_matmul_ref(x, w, mask_blocks, block_n=block_n)
    return _pallas(x, w, mask_blocks, block_n=block_n,
                   interpret=backend == "interpret", **kw)
