"""Mamba2 (SSD — state-space duality) mixer, pure-JAX chunked reference.

The chunked SSD algorithm (arXiv:2405.21060) processes the sequence in chunks:
inside a chunk the dual quadratic form is used (small Q x Q matmuls — MXU
friendly), between chunks a linear recurrence carries the [H, P, N] state.
We scan chunks sequentially (lax.scan), which bounds activation memory to one
chunk and maps 1:1 onto the Pallas kernel's sequential grid.

Layout notes: ngroups = 1 (public mamba2 configs), so B/C are shared across
heads.  Heads shard over `model` (logical axis "ssm_heads").
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import ShardingCtx
from repro.models.params import ParamSpec

f32 = jnp.float32


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim P, state N)."""
    d_in = cfg.ssm_expand * cfg.d_model
    return d_in, d_in // cfg.ssm_head_dim, cfg.ssm_head_dim, cfg.ssm_state


def mamba_specs(cfg: ModelConfig):
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    W = cfg.ssm_conv_width
    return {
        "wz": ParamSpec((d, d_in), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, d_in), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, N), ("embed", "ssm_state")),
        "wC": ParamSpec((d, N), ("embed", "ssm_state")),
        "wdt": ParamSpec((d, H), ("embed", "ssm_heads")),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), "zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), "ones", 0.5),   # A = -exp(A_log)
        "D": ParamSpec((H,), ("ssm_heads",), "ones"),
        "conv_x": ParamSpec((W, d_in), ("conv", "ssm_inner"), "normal", 0.5),
        "conv_x_bias": ParamSpec((d_in,), ("ssm_inner",), "zeros"),
        "conv_B": ParamSpec((W, N), ("conv", "ssm_state"), "normal", 0.5),
        "conv_C": ParamSpec((W, N), ("conv", "ssm_state"), "normal", 0.5),
        "gnorm": ParamSpec((d_in,), ("ssm_inner",), "zeros"),
        "wo": ParamSpec((d_in, d), ("ssm_inner", "embed")),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv (width-4) — shift-and-add, no conv primitive needed
# ---------------------------------------------------------------------------
def causal_conv(x, weight, bias=None):
    """x: [B, S, C]; weight: [W, C] depthwise."""
    W = weight.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(pad[:, w:w + S] * weight[w] for w in range(W))
    if bias is not None:
        out = out + bias
    return out


def conv_decode_step(conv_state, x_new, weight, bias=None):
    """conv_state: [B, W-1, C]; x_new: [B, C] -> (y [B, C], new_state)."""
    full = jnp.concatenate([conv_state, x_new[:, None]], axis=1)   # [B, W, C]
    y = jnp.einsum("bwc,wc->bc", full, weight)
    if bias is not None:
        y = y + bias
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------
def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, initial_state=None):
    """Chunked state-space duality.

    x:  [B, S, H, P]     (already multiplied by nothing; dt applied inside)
    dt: [B, S, H]        (post-softplus, > 0)
    A:  [H]              (negative)
    Bm, Cm: [B, S, N]    (ngroups = 1)
    Returns (y [B, S, H, P], final_state [B, H, P, N]) — fp32 state.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    xc = x.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, nc, Q, H).transpose(1, 0, 2, 3).astype(f32)
    Bc = Bm.reshape(B, nc, Q, N).transpose(1, 0, 2, 3).astype(f32)
    Cc = Cm.reshape(B, nc, Q, N).transpose(1, 0, 2, 3).astype(f32)
    Af = A.astype(f32)

    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), f32)

    idx = jnp.arange(Q)
    causal = (idx[:, None] >= idx[None, :]).astype(f32)            # [Q, Q]

    def step(state, inp):
        xq, dtq, Bq, Cq = inp                   # [B,Q,H,P],[B,Q,H],[B,Q,N]x2
        xq = xq.astype(f32)
        dA = dtq * Af                            # [B,Q,H] (negative)
        cum = jnp.cumsum(dA, axis=1)             # [B,Q,H]
        # --- intra-chunk (dual quadratic form) ---
        CB = jnp.einsum("bin,bjn->bij", Cq, Bq)  # [B,Q,Q]
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])          # [B,Q,Q,H]
        T = CB[..., None] * decay * causal[None, :, :, None] * dtq[:, None]
        y = jnp.einsum("bijh,bjhp->bihp", T, xq)
        # --- contribution of carried state ---
        y = y + jnp.einsum("bin,bhpn,bih->bihp", Cq, state, jnp.exp(cum))
        # --- state update ---
        seg = jnp.exp(cum[:, -1:, :] - cum) * dtq                    # [B,Q,H]
        new_state = (state * jnp.exp(cum[:, -1])[..., None, None]
                     + jnp.einsum("bjh,bjhp,bjn->bhpn", seg, xq, Bq))
        return new_state, y

    with jax.named_scope("ssd_chunk"):
        if nc == 1:
            final, y = step(initial_state, jax.tree.map(lambda t: t[0],
                                                        (xc, dtc, Bc, Cc)))
            y = y[None]
        else:
            final, y = jax.lax.scan(step, initial_state, (xc, dtc, Bc, Cc))
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token recurrence.  x: [B,H,P], dt: [B,H], Bm/Cm: [B,N].

    Returns (y [B,H,P], new_state [B,H,P,N]).
    """
    xf, dtf = x.astype(f32), dt.astype(f32)
    dA = jnp.exp(dtf * A.astype(f32))[..., None, None]              # [B,H,1,1]
    upd = dtf[..., None, None] * xf[..., None] * Bm[:, None, None, :].astype(f32)
    new_state = state * dA + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 sublayer
# ---------------------------------------------------------------------------
def _gated_norm(params, y, z, cfg: ModelConfig):
    """RMSNormGated: RMSNorm(y * silu(z)) * (1 + w)."""
    g = (y * jax.nn.silu(z)).astype(f32)
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    out = g * jax.lax.rsqrt(var + cfg.norm_eps) * (1.0 + params["gnorm"].astype(f32))
    return out.astype(y.dtype)


def mamba_apply(params, x, cfg: ModelConfig, ctx: ShardingCtx, *,
                cache=None, channel_mask=None):
    """Mamba2 mixer.

    Train/prefill: cache None -> (out, (conv_states, ssm_state)) final states.
    Decode: cache = (conv_states [B, W-1, d_in + 2N], ssm_state [B,H,P,N]),
    x: [B, 1, d].  channel_mask: Horn per-group mask over d_inner ([B, 1, d_in]).
    """
    B, S, _ = x.shape
    d_in, H, P, N = ssm_dims(cfg)

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xs = jnp.einsum("bsd,de->bse", x, params["wx"])
    Bs = jnp.einsum("bsd,dn->bsn", x, params["wB"])
    Cs = jnp.einsum("bsd,dn->bsn", x, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"])
    dt = jax.nn.softplus(dt.astype(f32) + params["dt_bias"].astype(f32))
    A = -jnp.exp(params["A_log"].astype(f32))

    if cache is None:
        xs = jax.nn.silu(causal_conv(xs, params["conv_x"], params["conv_x_bias"]))
        Bs = jax.nn.silu(causal_conv(Bs, params["conv_B"]))
        Cs = jax.nn.silu(causal_conv(Cs, params["conv_C"]))
        if channel_mask is not None:
            xs = xs * channel_mask.astype(xs.dtype)
        xs = ctx.constrain(xs, "batch", "seq", "ssm_inner")
        xh = xs.reshape(B, S, H, P)
        y, final = ssd_chunked(xh, dt, A, Bs, Cs, chunk=cfg.ssm_chunk)
        y = y + xh * params["D"].astype(y.dtype)[:, None]
        # conv tail state for a later decode continuation
        tail = jnp.concatenate([xs, Bs, Cs], axis=-1)[:, -(cfg.ssm_conv_width - 1):]
        new_cache = (tail, final)
    else:
        conv_state, ssm_state = cache
        W = cfg.ssm_conv_width
        cx, cB, cC = jnp.split(conv_state, [d_in, d_in + N], axis=-1)
        xs1, cx = conv_decode_step(cx, xs[:, 0], params["conv_x"],
                                   params["conv_x_bias"])
        Bs1, cB = conv_decode_step(cB, Bs[:, 0], params["conv_B"])
        Cs1, cC = conv_decode_step(cC, Cs[:, 0], params["conv_C"])
        xs1, Bs1, Cs1 = map(jax.nn.silu, (xs1, Bs1, Cs1))
        if channel_mask is not None:
            xs1 = xs1 * channel_mask[:, 0].astype(xs1.dtype)
        xh = xs1.reshape(B, H, P)
        y, ssm_state = ssd_decode_step(ssm_state, xh, dt[:, 0], A, Bs1, Cs1)
        y = y + xh * params["D"].astype(y.dtype)[:, None]
        y = y[:, None]                                      # [B, 1, H, P]
        new_cache = (jnp.concatenate([cx, cB, cC], axis=-1), ssm_state)

    y = y.reshape(B, S, d_in)
    y = _gated_norm(params, y, z, cfg)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"])
    return ctx.constrain(out, "batch", "seq", "act_embed"), new_cache
