"""GQA attention: chunked online-softmax reference path + KV-cache decode.

The reference path (used by smoke tests and by the 512-device dry-run, where
Pallas cannot lower on the CPU backend) never materializes an [S, S] score
matrix: it scans over KV chunks with a running (max, sum, acc) — the same
algorithm the Pallas flash kernel implements in VMEM.  ``repro.kernels`` swaps
in the Pallas kernel on TPU via the backend switch in ``kernels/ops.py``.

Supports: GQA (kv_heads <= heads), qk-norm (qwen3), QKV bias (qwen1.5),
attention-logit softcapping (gemma2), sliding windows (gemma local layers),
query scaling overrides, cross-attention (whisper), single-token decode.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import ShardingCtx
from repro.models.layers import apply_rope, norm_apply
from repro.models.params import ParamSpec

f32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "kv_head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "kv_head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "kv_head_dim"), "zeros")
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "kv_head_dim"), "zeros")
    if cfg.qk_norm and not cross:
        specs["q_norm"] = {"scale": ParamSpec((hd,), ("noshard",), "zeros")}
        specs["k_norm"] = {"scale": ParamSpec((hd,), ("noshard",), "zeros")}
    return specs


# ---------------------------------------------------------------------------
# Core chunked attention
# ---------------------------------------------------------------------------
def _mask_block(q_pos, k_pos, *, causal: bool, window: Optional[int],
                kv_len: Optional[jnp.ndarray]):
    """Additive mask block [..., Sq, Skv_chunk] from absolute positions."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), f32)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m = jnp.where(kp > qp, NEG_INF, m)
    if window is not None:
        m = jnp.where(kp <= qp - window, NEG_INF, m)
    if kv_len is not None:  # decode: positions beyond current length are invalid
        m = jnp.where(kp >= kv_len[..., None, None], NEG_INF, m)
    return m


def chunked_attention(q, k, v, *, scale: float, causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_positions=None, k_positions=None,
                      kv_len=None, kv_chunk: int = 1024):
    """Online-softmax attention.

    q: [B, Sq, H, D]; k, v: [B, Skv, KH, D] with H = KH * G.
    Returns [B, Sq, H, D].  fp32 accumulation throughout.
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    kv_chunk = min(kv_chunk, Skv)
    while Skv % kv_chunk:
        kv_chunk //= 2
    n_chunks = Skv // kv_chunk

    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if k_positions is None:
        k_positions = jnp.arange(Skv)
    q_positions = jnp.broadcast_to(q_positions, (B, Sq))
    k_positions = jnp.broadcast_to(k_positions, (B, Skv))

    qg = q.reshape(B, Sq, KH, G, D).transpose(0, 2, 3, 1, 4)   # [B,KH,G,Sq,D]
    kc = k.transpose(0, 2, 1, 3).reshape(B, KH, n_chunks, kv_chunk, D)
    vc = v.transpose(0, 2, 1, 3).reshape(B, KH, n_chunks, kv_chunk, D)
    kpos_c = k_positions.reshape(B, n_chunks, kv_chunk)

    def step(carry, inp):
        acc, m_run, l_run = carry
        k_blk, v_blk, kp_blk = inp                              # [B,KH,C,D], [B,C]
        # bf16 inputs, f32 accumulation via preferred_element_type — avoids
        # materializing f32 copies of K/V (hillclimb 3: -2x attn traffic)
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qg, k_blk,
                       preferred_element_type=f32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = _mask_block(q_positions, kp_blk, causal=causal, window=window,
                           kv_len=kv_len)                       # [B,Sq,C]
        s = s + mask[:, None, None]
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=f32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, KH, G, Sq, D), f32)
    m0 = jnp.full((B, KH, G, Sq), NEG_INF, f32)
    l0 = jnp.zeros((B, KH, G, Sq), f32)
    xs = (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
          kpos_c.transpose(1, 0, 2))
    with jax.named_scope("flash_attn"):
        if n_chunks == 1:
            (acc, _, l), _ = step((acc0, m0, l0),
                                  jax.tree.map(lambda x: x[0], xs))
        else:
            # checkpoint the chunk step: backward recomputes p from (q, k)
            # instead of saving [n_chunks, ..., Sq, C] f32 score residuals
            # (hillclimb 3: the p-stack dominated attention HBM traffic)
            (acc, _, l), _ = jax.lax.scan(jax.checkpoint(step),
                                          (acc0, m0, l0), xs)
        out = acc / jnp.clip(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def cache_update(buf, new, pos, ctx: ShardingCtx):
    """Write one token into the KV cache at dynamic position ``pos``.

    When the cache's sequence axis is sharded (rule "kv_seq"), a plain
    dynamic_update_slice makes GSPMD all-gather the ENTIRE stacked cache
    (observed: 2 x 1.7e12 B for qwen1.5 decode_32k).  Instead we shard_map a
    local update: each shard tests whether ``pos`` falls in its range —
    zero collective bytes (EXPERIMENTS.md §Perf hillclimb 2).
    """
    if ctx.mesh is None or ctx.rules.get("kv_seq") is None:
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), pos, axis=1)
    from jax.sharding import PartitionSpec as P
    buf_spec = ctx.spec("batch", "kv_seq", "kv_heads", "kv_head_dim")
    new_spec = ctx.spec("batch", None, "kv_heads", "kv_head_dim")
    seq_axes = buf_spec[1]

    def upd(b, n, p):
        s_loc = b.shape[1]
        if seq_axes is None:
            start = 0
        else:
            start = jax.lax.axis_index(seq_axes) * s_loc
        lp = jnp.clip(p - start, 0, max(s_loc - 1, 0))
        in_range = jnp.logical_and(p >= start, p < start + s_loc)
        updated = jax.lax.dynamic_update_slice_in_dim(
            b, n.astype(b.dtype), lp, axis=1)
        return jnp.where(in_range, updated, b)

    from repro.launch.mesh import shard_map
    fn = shard_map(upd, mesh=ctx.mesh,
                   in_specs=(buf_spec, new_spec, P()),
                   out_specs=buf_spec, check_vma=False)
    return fn(buf, new, jnp.asarray(pos, jnp.int32))


def decode_attention(q, k_buf, v_buf, *, scale: float,
                     window, softcap, kv_len, q_positions, ctx):
    """Single-token attention over a full cache — no chunk scan.

    One masked softmax over [B, KH, G, 1, S]: GSPMD partitions the S axis
    (rule "kv_seq") with partial-softmax reductions (flash-decode), instead
    of the chunk-scan path whose sharded-xs scan made GSPMD all-gather the
    entire cache (EXPERIMENTS.md §Perf hillclimb 2).
    """
    B, Sq, H, D = q.shape
    S, KH = k_buf.shape[1], k_buf.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_buf,
                   preferred_element_type=f32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kp = jnp.arange(S)[None, :]
    mask = jnp.zeros((B, Sq, S), f32)
    qp = q_positions[..., :, None]
    if window is not None:
        mask = jnp.where(kp[:, None] <= qp - window, NEG_INF, mask)
    if kv_len is not None:
        mask = jnp.where(kp[:, None] >= kv_len[:, None, None], NEG_INF, mask)
    s = s + mask[:, None, None]                  # [B,KH,G,Sq,S]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v_buf.dtype), v_buf,
                     preferred_element_type=f32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention sublayer
# ---------------------------------------------------------------------------
def _project_qkv(params, x, kv_x, cfg: ModelConfig, positions, kv_positions,
                 *, use_rope: bool, rope_theta: float):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if "q_norm" in params:
        q = norm_apply(params["q_norm"], q, cfg)
        k = norm_apply(params["k_norm"], k, cfg)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kv_positions, rope_theta)
    return q, k, v


def attn_apply(params, x, cfg: ModelConfig, ctx: ShardingCtx, *,
               kind: str = "attn", positions=None, cache=None, cache_index=None,
               kv_x=None, cross: bool = False, head_mask=None,
               causal: bool = True, block_tables=None, chunk_lens=None):
    """Attention sublayer.

    Modes:
      - training/prefill: ``cache is None`` -> returns (out, new_kv) where
        new_kv=(k, v) so prefill can build a cache.
      - decode: ``cache=(k_buf, v_buf)`` [B, S_max, KH, D] and ``cache_index``
        scalar -> one-token update, returns (out, updated cache).
      - paged (the unified serving step): ``block_tables`` [B, maxp] given,
        ``cache`` is a (k_pages, v_pages) [P, psize, KH, D] pool pair,
        ``cache_index`` is a *per-sequence* [B] vector of KV tokens already
        in pages, and ``chunk_lens`` [B] counts the valid tokens of this
        call's [B, C] chunk (decode slots: 1; admitting prompts: up to C;
        idle slots: 0).  Chunk K/V is appended to the pool in place, then
        every token attends to prior pages plus its own chunk's causal
        prefix (continuous batching: every slot sits at its own depth).
      - cross-attention: ``kv_x`` given, no cache/rope on kv side.
    """
    B, Sq, _ = x.shape
    window = cfg.sliding_window if kind == "local" else None
    theta = 10_000.0 if (kind == "local" and cfg.rope_theta > 1e5) else cfg.rope_theta
    # gemma2 scales queries by query_pre_attn_scalar instead of head_dim
    scale = cfg.query_scale if cfg.query_scale else cfg.head_dim ** -0.5
    use_rope = cfg.use_rope and not cross

    if positions is None:
        positions = jnp.arange(Sq)[None, :]
    kv_src = kv_x if cross else x

    if cache is None:
        kv_positions = jnp.arange(kv_src.shape[1])[None, :] if cross else positions
        q, k, v = _project_qkv(params, x, kv_src, cfg, positions, kv_positions,
                               use_rope=use_rope, rope_theta=theta)
        if ctx.rules.get("sp_seq") is not None:
            # sequence-parallel attention (prefill w/ unshardable heads)
            q = ctx.constrain(q, "batch", "sp_seq", "heads", "head_dim")
        else:
            q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
        k = ctx.constrain(k, "batch", "seq", "kv_heads", "kv_head_dim")
        v = ctx.constrain(v, "batch", "seq", "kv_heads", "kv_head_dim")
        from repro.kernels.backend import get_backend
        if get_backend() != "ref" and not cross:
            # production TPU path: Pallas flash kernel ([B,H,S,D] layout)
            from repro.kernels.flash_attention.kernel import flash_attention
            out = flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), scale=scale, causal=causal,
                window=window, softcap=cfg.attn_logit_softcap,
                interpret=get_backend() == "interpret",
            ).transpose(0, 2, 1, 3)
        else:
            out = chunked_attention(
                q, k, v, scale=scale, causal=causal and not cross,
                window=window, softcap=cfg.attn_logit_softcap,
                q_positions=positions, k_positions=kv_positions)
        new_kv = (k, v)
    elif block_tables is not None:
        # unified paged step: per-sequence chunk append + paged attention.
        # ``cache`` is (k_pages, v_pages) — or, in int8-pool mode,
        # (k_pages, v_pages, k_scale, v_scale) with per-(page, kv-head)
        # scales riding beside the pools: the append path quantizes
        # in-device and the kernel dequantizes in-register after the gather
        from repro.kernels.paged_attention.ops import (
            paged_chunk_attention, paged_pool_append, paged_pool_append_quant)
        quantized = len(cache) == 4
        if quantized:
            k_pages, v_pages, k_scale, v_scale = cache
        else:
            (k_pages, v_pages), k_scale, v_scale = cache, None, None
        if chunk_lens is None:                          # plain decode tick
            chunk_lens = jnp.ones((B,), jnp.int32)
        q, k_new, v_new = _project_qkv(
            params, x, kv_src, cfg, positions, positions,
            use_rope=use_rope, rope_theta=theta)
        if quantized:
            k_pages, k_scale = paged_pool_append_quant(
                k_pages, k_scale, k_new, block_tables, cache_index, chunk_lens)
            v_pages, v_scale = paged_pool_append_quant(
                v_pages, v_scale, v_new, block_tables, cache_index, chunk_lens)
        else:
            k_pages = paged_pool_append(k_pages, k_new, block_tables,
                                        cache_index, chunk_lens)
            v_pages = paged_pool_append(v_pages, v_new, block_tables,
                                        cache_index, chunk_lens)
        out = paged_chunk_attention(
            q, k_pages, v_pages, block_tables, cache_index, chunk_lens,
            scale=scale, window=window, softcap=cfg.attn_logit_softcap,
            k_scale=k_scale, v_scale=v_scale)
        new_kv = (k_pages, v_pages, k_scale, v_scale) if quantized \
            else (k_pages, v_pages)
    else:
        # single-token decode against a preallocated cache
        k_buf, v_buf = cache
        q, k_new, v_new = _project_qkv(
            params, x, kv_src, cfg, positions, positions,
            use_rope=use_rope, rope_theta=theta)
        if not cross:
            k_buf = cache_update(k_buf, k_new, cache_index, ctx)
            v_buf = cache_update(v_buf, v_new, cache_index, ctx)
        kv_len = None if cross else jnp.full((B,), cache_index + Sq)
        k_buf = ctx.constrain(k_buf, "batch", "kv_seq", "kv_heads",
                              "kv_head_dim")
        v_buf = ctx.constrain(v_buf, "batch", "kv_seq", "kv_heads",
                              "kv_head_dim")
        out = decode_attention(
            q, k_buf, v_buf, scale=scale, window=window,
            softcap=cfg.attn_logit_softcap, kv_len=kv_len,
            q_positions=positions, ctx=ctx)
        new_kv = (k_buf, v_buf)

    if head_mask is not None:  # Horn per-group head dropout (optional)
        out = out * head_mask.astype(out.dtype)
    # row-parallel out-proj: keep the TP psum in the activation dtype
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                      preferred_element_type=x.dtype)
    return ctx.constrain(proj, "batch", "seq", "act_embed"), new_kv
