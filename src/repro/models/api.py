"""Unified model API over all assigned architectures.

``batch`` dict keys by family:
  all:    tokens [B, S_text] int32, labels [B, S_text] int32
  audio:  frames [B, encoder_seq, d_model]      (stub frontend)
  vlm:    patch_embeds [B, num_patches, d_model] (stub frontend; prefix fusion)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import ShardingCtx
from repro.models import transformer as T
from repro.models import encdec as ED
from repro.models.params import param_axes

f32 = jnp.float32


def model_specs(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return ED.encdec_specs(cfg)
    return T.lm_specs(cfg)


def model_init(key, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return ED.encdec_init(key, cfg)
    return T.lm_init(key, cfg)


def model_axes(cfg: ModelConfig):
    return param_axes(model_specs(cfg))


def _decoder_params(params, cfg):
    return params["decoder"] if cfg.is_encoder_decoder else params


def forward_hidden(params, batch: Dict[str, Any], cfg: ModelConfig,
                   ctx: ShardingCtx, *, horn=None, mode: str = "train",
                   remat: bool = True, cache=None, cache_index=None,
                   encoder_out=None, block_tables=None, chunk_lens=None,
                   serve_masks=None, logit_index=None):
    """Returns (hidden, new_cache, aux, encoder_out).

    ``serve_masks`` carries fixed per-slot sub-model masks (multi-submodel
    serving, see ``transformer.lm_forward``) — decoder-LM-only, like
    ``logit_index`` (the fused verify window, see ``lm_forward``).
    """
    if cfg.is_encoder_decoder:
        if block_tables is not None:
            raise ValueError("paged decode is decoder-LM-only")
        if serve_masks is not None:
            raise ValueError("sub-model serving masks are decoder-LM-only")
        hidden, new_cache, aux, enc = ED.encdec_forward(
            params, batch.get("frames"), batch["tokens"], cfg, ctx, horn=horn,
            cache=cache, cache_index=cache_index, mode=mode, remat=remat,
            encoder_out=encoder_out)
        return hidden, new_cache, aux, enc
    hidden, new_cache, aux = T.lm_forward(
        params, batch["tokens"], cfg, ctx, horn=horn,
        patch_embeds=batch.get("patch_embeds"), cache=cache,
        cache_index=cache_index, mode=mode, remat=remat,
        block_tables=block_tables, chunk_lens=chunk_lens,
        serve_masks=serve_masks, logit_index=logit_index)
    return hidden, new_cache, aux, None


def model_loss(params, batch, cfg: ModelConfig, ctx: ShardingCtx, *,
               horn=None, remat: bool = True,
               lb_coef: float = 0.01, z_coef: float = 1e-3):
    """Scalar loss + metrics.  Labels cover the text positions only."""
    hidden, _, aux, _ = forward_hidden(params, batch, cfg, ctx, horn=horn,
                                       mode="train", remat=remat)
    if cfg.num_patches and "patch_embeds" in batch:
        hidden = hidden[:, batch["patch_embeds"].shape[1]:]
    dec_params = _decoder_params(params, cfg)
    xent = T.chunked_xent(hidden, dec_params, batch["labels"], cfg, ctx)
    loss = xent
    if cfg.num_experts:
        loss = loss + lb_coef * aux["load_balance_loss"] \
                    + z_coef * aux["router_z_loss"]
    metrics = {"loss": loss, "xent": xent, **aux}
    return loss, metrics


def prefill(params, batch, cfg: ModelConfig, ctx: ShardingCtx, *,
            last_index=None, serve_masks=None):
    """Full-sequence forward for serving; returns last-position logits + cache.

    ``last_index`` ([B] int32, optional) selects the position whose logits
    are returned — needed when prompts are right-padded to a bucket length
    (the serving engine), where position -1 is a pad token.
    ``serve_masks`` selects a fixed sub-model per slot (ModelBank row,
    already gathered) — used by the masked-vs-materialized parity tests and
    by dense references for the multi-submodel engine.
    """
    hidden, cache, _, enc = forward_hidden(params, batch, cfg, ctx,
                                           mode="prefill", remat=False,
                                           serve_masks=serve_masks)
    if last_index is None:
        h_last = hidden[:, -1:]
    else:
        h_last = jnp.take_along_axis(
            hidden, last_index[:, None, None].astype(jnp.int32), axis=1)
    dec_params = _decoder_params(params, cfg)
    logits = T.lm_logits(dec_params, h_last, cfg, ctx)
    return logits[:, 0], cache, enc


def paged_step(params, cache, tokens, starts, chunk_lens, block_tables,
               cfg: ModelConfig, ctx: ShardingCtx, *, serve_masks=None,
               logit_index=None):
    """One unified serving tick over paged KV pools: every slot advances by
    a chunk of up to C tokens (decode slots: exactly 1; admitting prompts:
    a prompt chunk; idle slots: 0 — the scheduler packs them into one token
    budget).  The chunk K/V is appended to the pool in place.

    tokens: [B, C] right-padded chunks; starts: [B] KV tokens already in
    pages per slot; chunk_lens: [B] valid tokens per chunk; block_tables:
    [B, maxp] page ids (empty slots: all-zero rows -> null page).
    Returns (logits [B, vocab] at each slot's last *valid* chunk position,
    new_cache).  Idle slots return garbage logits the caller must ignore.

    ``logit_index`` ([B, n] int32, optional) instead selects n chunk
    positions per slot for the lm head — the speculative verify window:
    position j's logits are the parent's distribution for the token AFTER
    chunk token j, so one call scores every drafted continuation.  Returns
    (logits [B, n, vocab], new_cache).  Still never materializes [B, C, V]:
    the head runs on exactly the gathered positions (n == chunk width only
    when every position is verified).

    The window is *fused into the forward* (``lm_forward(logit_index=...)``)
    rather than gathered from full-width hidden here: the residual stream
    is windowed right after the final block and the final norm runs on the
    window rows only — bitwise identical to the post-norm gather (row-wise
    norm), one less full-width pass.  The non-verify path uses the same
    fusion with the [B, 1] last-valid-position window.
    """
    dec_params = _decoder_params(params, cfg)
    if logit_index is not None:
        hidden, new_cache, _, _ = forward_hidden(
            params, {"tokens": tokens}, cfg, ctx, mode="decode", remat=False,
            cache=cache, cache_index=starts, block_tables=block_tables,
            chunk_lens=chunk_lens, serve_masks=serve_masks,
            logit_index=logit_index)
        return T.lm_logits(dec_params, hidden, cfg, ctx), new_cache
    # the lm head runs on one position per slot, not the whole chunk — at
    # vocab 150k+ the [B, C, V] logits would dwarf the forward itself
    hidden, new_cache, _, _ = forward_hidden(
        params, {"tokens": tokens}, cfg, ctx, mode="decode", remat=False,
        cache=cache, cache_index=starts, block_tables=block_tables,
        chunk_lens=chunk_lens, serve_masks=serve_masks,
        logit_index=jnp.maximum(chunk_lens - 1, 0)[:, None])
    logits = T.lm_logits(dec_params, hidden, cfg, ctx)
    return logits[:, 0], new_cache


def decode_step(params, cache, tokens, cache_index, cfg: ModelConfig,
                ctx: ShardingCtx, *, encoder_out=None, serve_masks=None):
    """One-token decode.  tokens: [B, 1]; cache_index: scalar int32 position.

    Returns (logits [B, vocab], new_cache).
    """
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder and encoder_out is None:
        raise ValueError("enc-dec decode requires encoder_out")
    hidden, new_cache, _, _ = forward_hidden(
        params, batch, cfg, ctx, mode="decode", remat=False, cache=cache,
        cache_index=cache_index, encoder_out=encoder_out,
        serve_masks=serve_masks)
    dec_params = _decoder_params(params, cfg)
    logits = T.lm_logits(dec_params, hidden, cfg, ctx)
    return logits[:, 0], new_cache
