"""Encoder-decoder (Whisper-style).  Conv/audio frontend is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
[B, encoder_seq, d_model]; the transformer backbone is real.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.launch.mesh import ShardingCtx
from repro.models import layers as L
from repro.models.params import ParamSpec, init_params, param_axes, stack_specs
from repro.models.transformer import (_block_apply, block_specs, lm_forward,
                                      lm_specs)


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, num_layers=cfg.num_encoder_layers, layer_pattern=(ATTN,),
        is_encoder_decoder=False, moe_period=0, max_pos=cfg.encoder_seq)


def encdec_specs(cfg: ModelConfig):
    ecfg = encoder_cfg(cfg)
    enc: Dict[str, Any] = {
        "pos_embed": ParamSpec((ecfg.max_pos, cfg.d_model),
                               ("noshard", "embed"), "normal", 0.02),
        "blocks": stack_specs(
            {"l0": block_specs(ecfg, ATTN, False)}, ecfg.num_layers),
        "final_norm": L.norm_specs(cfg),
    }
    return {"encoder": enc, "decoder": lm_specs(cfg, cross=True)}


def encdec_init(key, cfg: ModelConfig):
    return init_params(key, encdec_specs(cfg))


def encdec_axes(cfg: ModelConfig):
    return param_axes(encdec_specs(cfg))


def encode(params, frames, cfg: ModelConfig, ctx: ShardingCtx, *,
           remat: bool = True, train: bool = False):
    """frames: [B, S_enc, d] stub embeddings -> encoder hidden states."""
    ecfg = encoder_cfg(cfg)
    x = frames + params["pos_embed"][: frames.shape[1]].astype(frames.dtype)[None]
    x = ctx.constrain(x, "batch", "seq", "act_embed")

    def body(carry, sb):
        h, _, _ = _block_apply(sb["l0"], carry, ecfg, ctx, kind=ATTN,
                               is_moe=False, layer_idx=0, horn=None,
                               positions=jnp.arange(x.shape[1])[None, :],
                               cache=None, cache_index=None, causal=False)
        return h, None

    fn = jax.checkpoint(body) if (remat and train) else body
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    return L.norm_apply(params["final_norm"], x, cfg)


def encdec_forward(params, frames, tokens, cfg: ModelConfig, ctx: ShardingCtx,
                   *, horn=None, cache=None, cache_index=None,
                   mode: str = "train", remat: bool = True, encoder_out=None):
    """Full enc-dec forward.  For decode, pass precomputed ``encoder_out``."""
    if encoder_out is None:
        encoder_out = encode(params["encoder"], frames, cfg, ctx,
                             remat=remat, train=mode == "train")
    hidden, new_cache, aux = lm_forward(
        params["decoder"], tokens, cfg, ctx, horn=horn, cache=cache,
        cache_index=cache_index, mode=mode, remat=remat,
        encoder_out=encoder_out)
    return hidden, new_cache, aux, encoder_out
