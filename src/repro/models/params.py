"""Single-source-of-truth parameter declaration.

Each layer declares its parameters once as ``ParamSpec``s (shape + logical axes
+ initializer); both the init function and the logical-axes tree derive from the
same specs, so sharding metadata can never drift from the arrays.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float = 1.0                    # stddev for normal / value scale
    dtype: str = "float32"
    fan_in: Optional[int] = None          # explicit fan-in (survives stacking)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: ParamSpec):
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt) * spec.scale
    if spec.init == "normal":
        fan_in = spec.fan_in or (spec.shape[0] if spec.shape else 1)
        std = spec.scale / np.sqrt(max(1, fan_in))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    if spec.init == "uniform":
        return jax.random.uniform(key, spec.shape, dt, -spec.scale, spec.scale)
    raise ValueError(spec.init)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(key, specs):
    """Materialize a pytree of ParamSpec into arrays (deterministic per-path keys)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def param_axes(specs):
    """The logical-axes pytree matching ``init_params``'s output structure."""
    return jax.tree.map(lambda s: tuple(s.axes), specs, is_leaf=is_spec)


def param_shapes(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=is_spec)


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scanned) leading dim to every spec in the tree.

    Preserves fan-in so e.g. a (d, ff) matrix stacked to (L, d, ff) still
    initializes with std ~ 1/sqrt(d), not 1/sqrt(L).
    """
    def f(s: ParamSpec) -> ParamSpec:
        fan = s.fan_in or (s.shape[0] if s.shape else 1)
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                         s.scale, s.dtype, fan)
    return jax.tree.map(f, specs, is_leaf=is_spec)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
