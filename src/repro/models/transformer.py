"""Decoder LM assembly: scan-over-superblocks, heterogeneous mixers, caches.

The layer stack is expressed as a repeating *superblock* (``cfg.layer_pattern``)
scanned ``pattern_repeats`` times with stacked parameters — HLO size scales
with the superblock, not the depth (critical for 512-device compiles and real
TPU compile times).  Remainder layers (e.g. gemma3's trailing 4 local layers)
are applied unscanned.

Supports train / prefill (returns KV+SSM caches) / single-token decode, VLM
prefix embeddings (stub frontends), cross-attention to an encoder (whisper),
Horn parallel-dropout hooks, MoE aux losses, and remat per superblock.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, MAMBA, ModelConfig
from repro.core import parallel_dropout as pdrop
from repro.launch.mesh import ShardingCtx
from repro.models import layers as L
from repro.models.attention import attn_apply, attn_specs
from repro.models.params import ParamSpec, init_params, param_axes, stack_specs
from repro.models.ssm import mamba_apply, mamba_specs, ssm_dims

f32 = jnp.float32


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def block_specs(cfg: ModelConfig, kind: str, is_moe: bool, *,
                cross: bool = False):
    s: Dict[str, Any] = {"pre_norm": L.norm_specs(cfg)}
    if kind in (ATTN, LOCAL):
        s["attn"] = attn_specs(cfg)
    elif kind == MAMBA:
        s["mamba"] = mamba_specs(cfg)
    else:
        raise ValueError(kind)
    if cfg.post_sublayer_norm:
        s["post_mixer_norm"] = L.norm_specs(cfg)
    if cross:
        s["cross_norm"] = L.norm_specs(cfg)
        s["cross_attn"] = attn_specs(cfg, cross=True)
    if is_moe or cfg.d_ff > 0:
        s["ffn_norm"] = L.norm_specs(cfg)
        if is_moe:
            s["moe"] = L.moe_specs(cfg)
        else:
            s["mlp"] = L.mlp_specs(cfg)
        if cfg.post_sublayer_norm:
            s["post_ffn_norm"] = L.norm_specs(cfg)
    return s


def lm_specs(cfg: ModelConfig, *, cross: bool = False):
    specs: Dict[str, Any] = {"embed": L.embed_specs(cfg)}
    R = cfg.pattern_repeats
    pat = cfg.layer_pattern
    if R:
        sb = {f"l{i}": block_specs(cfg, k, cfg.layer_is_moe(i), cross=cross)
              for i, k in enumerate(pat)}
        specs["blocks"] = stack_specs(sb, R)
    if cfg.pattern_remainder:
        specs["rem"] = {
            f"r{i}": block_specs(cfg, pat[i],
                                 cfg.layer_is_moe(R * len(pat) + i), cross=cross)
            for i in range(cfg.pattern_remainder)}
    specs["final_norm"] = L.norm_specs(cfg)
    if cfg.learned_pos:
        specs["pos_embed"] = ParamSpec((cfg.max_pos, cfg.d_model),
                                       ("noshard", "embed"), "normal", 0.02)
    return specs


def lm_init(key, cfg: ModelConfig, *, cross: bool = False):
    return init_params(key, lm_specs(cfg, cross=cross))


def lm_axes(cfg: ModelConfig, *, cross: bool = False):
    return param_axes(lm_specs(cfg, cross=cross))


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------
def _mix_mask(a, b):
    """Compose two optional multiplicative masks (either may be None)."""
    if a is None:
        return b
    return a if b is None else a * b


def _serve_slice(serve_masks, key: str, layer_idx):
    """Layer ``layer_idx``'s per-slot sub-model mask ([B, units]) from a
    serve-mask dict, or None.  ``layer_idx`` may be traced (the superblock
    scan) — the [G-gathered B, L, units] tensor is indexed dynamically."""
    if serve_masks is None or key not in serve_masks:
        return None
    return serve_masks[key][:, layer_idx]


def _block_apply(bp, x, cfg: ModelConfig, ctx: ShardingCtx, *, kind: str,
                 is_moe: bool, layer_idx, horn, positions, cache,
                 cache_index, encoder_out=None, causal: bool = True,
                 block_tables=None, chunk_lens=None, serve_masks=None):
    """Returns (x, new_mix_cache, aux)."""
    B = x.shape[0]
    aux: Dict[str, Any] = {}
    h = L.norm_apply(bp["pre_norm"], x, cfg)
    if kind in (ATTN, LOCAL):
        hm = pdrop.head_mask(horn, layer_idx, B, cfg.num_heads)
        sh = _serve_slice(serve_masks, "heads", layer_idx)
        if sh is not None:
            hm = _mix_mask(hm, sh[:, None, :, None])       # [B,1,H,1]
        out, new_mix_cache = attn_apply(
            bp["attn"], h, cfg, ctx, kind=kind, positions=positions,
            cache=cache, cache_index=cache_index, head_mask=hm, causal=causal,
            block_tables=block_tables, chunk_lens=chunk_lens)
    else:
        d_in = ssm_dims(cfg)[0]
        cm = pdrop.unit_mask(horn, layer_idx, B, d_in, salt=3)
        out, new_mix_cache = mamba_apply(
            bp["mamba"], h, cfg, ctx, cache=cache, channel_mask=cm)
    if cfg.post_sublayer_norm:
        out = L.norm_apply(bp["post_mixer_norm"], out, cfg)
    x = x + out.astype(x.dtype)

    if "cross_attn" in bp and encoder_out is not None:
        h = L.norm_apply(bp["cross_norm"], x, cfg)
        out, _ = attn_apply(bp["cross_attn"], h, cfg, ctx, cross=True,
                            positions=positions, kv_x=encoder_out)
        x = x + out.astype(x.dtype)

    if "ffn_norm" in bp:   # mamba2-style blocks have no FFN (d_ff == 0)
        h = L.norm_apply(bp["ffn_norm"], x, cfg)
        if is_moe:
            mm = pdrop.unit_mask(horn, layer_idx, B, cfg.moe_ff, salt=5)
            mm = None if mm is None else mm[:, None]       # [B,1,1,ff]
            sm = _serve_slice(serve_masks, "moe", layer_idx)
            if sm is not None:
                mm = _mix_mask(mm, sm[:, None, None, :])
            out, aux = L.moe_apply(bp["moe"], h, cfg, ctx, hidden_mask=mm)
        else:
            fm = pdrop.unit_mask(horn, layer_idx, B, cfg.d_ff, salt=5)
            sf = _serve_slice(serve_masks, "ffn", layer_idx)
            if sf is not None:
                fm = _mix_mask(fm, sf[:, None, :])         # [B,1,ff]
            out = L.mlp_apply(bp["mlp"], h, cfg, ctx, hidden_mask=fm)
        if cfg.post_sublayer_norm:
            out = L.norm_apply(bp["post_ffn_norm"], out, cfg)
        x = x + out.astype(x.dtype)
    return x, new_mix_cache, aux


def _empty_aux():
    return {"load_balance_loss": jnp.zeros((), f32),
            "router_z_loss": jnp.zeros((), f32),
            "dropped_frac": jnp.zeros((), f32)}


def _pad_aux(aux):
    base = _empty_aux()
    base.update(aux)
    return base


# ---------------------------------------------------------------------------
# Decode cache construction
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode caches, structured to match the scan (stacked per superblock)."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim

    def mix_cache(kind):
        if kind in (ATTN, LOCAL):
            shape = (batch, max_len, kv, hd)
            return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
        d_in, H, P, N = ssm_dims(cfg)
        conv = jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in + 2 * N), dtype)
        return (conv, jnp.zeros((batch, H, P, N), f32))

    R = cfg.pattern_repeats
    cache: Dict[str, Any] = {}
    if R:
        sb = {f"l{i}": mix_cache(k) for i, k in enumerate(cfg.layer_pattern)}
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), sb)
    if cfg.pattern_remainder:
        cache["rem"] = {f"r{i}": mix_cache(cfg.layer_pattern[i])
                        for i in range(cfg.pattern_remainder)}
    return cache


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=jnp.bfloat16):
    """Block-paged decode caches: every attention layer gets a pool of
    ``num_pages`` fixed-size pages [P, psize, KH, D] addressed through a
    shared per-sequence block table (page ids are layer-agnostic: page j of
    layer 0 and page j of layer 7 belong to the same sequence).  Page 0 is
    reserved as the null page for empty decode slots.  Structured to match
    the superblock scan, like ``init_cache``.

    ``dtype=jnp.int8`` selects the quantized-pool mode: each layer carries
    (k_pages int8, v_pages int8, k_scale f32 [P, KH], v_scale f32 [P, KH])
    — one symmetric scale per (page, kv-head) stored beside the pool, so a
    page costs ~1/2 the HBM of bf16 (~1/4 of f32) and the sidecar follows
    the page through every COW copy (same page ids index both arrays)."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    quantized = jnp.dtype(dtype) == jnp.int8

    def mix_cache(kind):
        if kind not in (ATTN, LOCAL):
            raise ValueError(
                f"paged KV cache supports attention mixers only, got {kind!r} "
                "(SSM states are slot-resident, not paged — see ROADMAP)")
        pools = (jnp.zeros((num_pages, page_size, kv, hd), dtype),
                 jnp.zeros((num_pages, page_size, kv, hd), dtype))
        if quantized:
            pools += (jnp.zeros((num_pages, kv), jnp.float32),
                      jnp.zeros((num_pages, kv), jnp.float32))
        return pools

    R = cfg.pattern_repeats
    cache: Dict[str, Any] = {}
    if R:
        sb = {f"l{i}": mix_cache(k) for i, k in enumerate(cfg.layer_pattern)}
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), sb)
    if cfg.pattern_remainder:
        cache["rem"] = {f"r{i}": mix_cache(cfg.layer_pattern[i])
                        for i in range(cfg.pattern_remainder)}
    return cache


def cache_logical_axes(cfg: ModelConfig, cache):
    """Logical-axes pytree matching ``init_cache`` output (for shardings)."""
    if cfg.ssm_state:
        d_in, H, P, N = ssm_dims(cfg)
    else:
        d_in = H = P = N = -1

    def ax(x):
        s = x.shape
        if len(s) >= 4 and s[-1] == cfg.head_dim and s[-2] == cfg.num_kv_heads:
            base = ("batch", "kv_seq", "kv_heads", "kv_head_dim")  # KV buffer
        elif len(s) >= 4 and s[-1] == N and s[-2] == P:
            base = ("batch", "ssm_heads", None, "ssm_state")     # SSM state
        elif len(s) >= 3 and s[-1] == d_in + 2 * N:
            base = ("batch", None, None)                          # conv tail
        else:
            return tuple(None for _ in s)
        if x.ndim == len(base) + 1:
            base = ("layers",) + base
        return base

    return jax.tree.map(ax, cache)


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------
def lm_forward(params, tokens, cfg: ModelConfig, ctx: ShardingCtx, *,
               horn=None, patch_embeds=None, cache=None, cache_index=None,
               mode: str = "train", remat: bool = True, encoder_out=None,
               causal: bool = True, block_tables=None, chunk_lens=None,
               serve_masks=None, logit_index=None):
    """Returns (hidden [B,S,d], new_cache or None, aux dict).

    ``logit_index`` ([B, n] int32, paged decode only) fuses the verify /
    last-position window into the forward: the n selected chunk rows are
    gathered from the residual stream right after the final block, and the
    final norm runs on those n rows only — the returned hidden is [B, n, d]
    and no full-width post-norm tensor is ever materialized.  Bitwise
    identical to gathering after the norm (the norm is row-wise).

    mode: "train" (no cache out, remat on) | "prefill" (cache out = full-seq
    KV / final SSM states) | "decode" (cache required; S is 1 for dense-cache
    decode, or the chunk width C for the unified paged step).

    Paged (unified serving step): pass ``block_tables`` [B, maxp], a
    per-sequence [B] ``cache_index`` (KV tokens already in pages — each slot
    at its own depth) and ``chunk_lens`` [B] (valid tokens of each slot's
    [B, C] chunk); ``cache`` must come from ``init_paged_cache``.  Token j of
    slot b sits at absolute position ``cache_index[b] + j``.

    ``serve_masks`` (multi-submodel serving) is a dict of *fixed per-slot*
    sub-model masks, already gathered by submodel id: "input" [B, d_model],
    "ffn" [B, L, d_ff], "moe" [B, L, moe_ff], "heads" [B, L, H] — binary
    {0, 1}, applied multiplicatively so each slot runs its own Horn circuit
    of the shared parent weights.  Orthogonal to ``horn`` (train-time
    stochastic masks); serving passes ``horn=None``.
    """
    decode = mode == "decode"
    x = L.embed_apply(params["embed"], tokens, cfg, ctx)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    B, Stot = x.shape[0], x.shape[1]
    if cfg.learned_pos:
        if decode:
            pos_emb = jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache_index, Stot, axis=0)
        else:
            pos_emb = params["pos_embed"][:Stot]
        x = x + pos_emb.astype(x.dtype)[None]

    im = pdrop.input_mask(horn, B, cfg.d_model)
    if im is not None:
        x = x * im.astype(x.dtype)
    if serve_masks is not None and "input" in serve_masks:
        x = x * serve_masks["input"][:, None, :].astype(x.dtype)

    if decode:
        ci = jnp.asarray(cache_index)
        start = ci[:, None] if ci.ndim == 1 else jnp.full((B, 1), ci)
        positions = start + jnp.arange(Stot)[None, :]   # per-token positions
    else:
        positions = jnp.arange(Stot)[None, :]
    pat = cfg.layer_pattern
    R = cfg.pattern_repeats
    new_cache: Dict[str, Any] = {}
    aux0 = _empty_aux()

    def superblock(x, aux_acc, sb_params, sb_cache, r):
        caches_out = {}
        for i, kind in enumerate(pat):
            li = r * len(pat) + i
            x, mix_c, aux = _block_apply(
                sb_params[f"l{i}"], x, cfg, ctx, kind=kind,
                is_moe=cfg.layer_is_moe(i), layer_idx=li, horn=horn,
                positions=positions,
                cache=None if sb_cache is None else sb_cache[f"l{i}"],
                cache_index=cache_index, encoder_out=encoder_out,
                causal=causal, block_tables=block_tables,
                chunk_lens=chunk_lens, serve_masks=serve_masks)
            caches_out[f"l{i}"] = mix_c
            aux_acc = jax.tree.map(jnp.add, aux_acc, _pad_aux(aux))
        return x, aux_acc, caches_out

    if R:
        if decode:
            def body(carry, inp):
                x, acc = carry
                sb_params, sb_cache, r = inp
                x, acc, caches = superblock(x, acc, sb_params, sb_cache, r)
                return (x, acc), caches
            xs = (params["blocks"], cache["blocks"], jnp.arange(R))
        else:
            def body(carry, inp):
                x, acc = carry
                sb_params, r = inp
                x, acc, caches = superblock(x, acc, sb_params, None, r)
                return (x, acc), caches
            xs = (params["blocks"], jnp.arange(R))
        if remat and mode == "train":
            body = jax.checkpoint(body)
        (x, aux0), caches_stacked = jax.lax.scan(body, (x, aux0), xs)
        if mode != "train":
            new_cache["blocks"] = caches_stacked

    if cfg.pattern_remainder:
        rem_cache = {}
        for i in range(cfg.pattern_remainder):
            li = R * len(pat) + i
            x, mix_c, aux = _block_apply(
                params["rem"][f"r{i}"], x, cfg, ctx, kind=pat[i],
                is_moe=cfg.layer_is_moe(li), layer_idx=li, horn=horn,
                positions=positions,
                cache=None if not decode else cache["rem"][f"r{i}"],
                cache_index=cache_index, encoder_out=encoder_out,
                causal=causal, block_tables=block_tables,
                chunk_lens=chunk_lens, serve_masks=serve_masks)
            rem_cache[f"r{i}"] = mix_c
            aux0 = jax.tree.map(jnp.add, aux0, _pad_aux(aux))
        if mode != "train":
            new_cache["rem"] = rem_cache

    if logit_index is not None:
        x = jnp.take_along_axis(
            x, logit_index[..., None].astype(jnp.int32), axis=1)
    x = L.norm_apply(params["final_norm"], x, cfg)
    aux_mean = jax.tree.map(lambda v: v / max(1, cfg.num_layers), aux0)
    return x, (new_cache if mode != "train" else None), aux_mean


# ---------------------------------------------------------------------------
# Losses / heads
# ---------------------------------------------------------------------------
def chunked_xent(hidden, params, labels, cfg: ModelConfig, ctx: ShardingCtx,
                 *, chunk: int = 512, label_mask=None):
    """Cross-entropy without materializing full [B, S, V] logits.

    Scans over sequence chunks; each chunk computes logits + log-softmax and
    is rematerialized in backward.  Essential at vocab 262k x seq 4k.
    """
    B, Stot, D = hidden.shape
    chunk = min(chunk, Stot)
    while Stot % chunk:
        chunk //= 2
    n = Stot // chunk
    hc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    if label_mask is None:
        label_mask = jnp.ones(labels.shape, f32)
    mc = label_mask.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(carry, inp):
        h, lbl, m = inp
        with jax.named_scope("xent_chunk"):
            logits = L.unembed_apply(params["embed"], h, cfg, ctx).astype(f32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
            nll = (lse - gold) * m
        loss, cnt = carry
        return (loss + nll.sum(), cnt + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(one, (jnp.zeros((), f32), jnp.zeros((), f32)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_logits(params, hidden, cfg: ModelConfig, ctx: ShardingCtx):
    return L.unembed_apply(params["embed"], hidden, cfg, ctx)
