"""Shared layers: norms, RoPE, gated MLP, sort-based MoE, embeddings.

All modules follow the same convention:
  ``<name>_specs(cfg) -> pytree[ParamSpec]``   (single source of truth)
  ``<name>_apply(params, x, cfg, ctx, ...)``   (pure function)
Sharding is expressed through ``ctx.constrain`` with logical axes only.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import ShardingCtx
from repro.models.params import ParamSpec

f32 = jnp.float32

ACTS = {
    "silu": jax.nn.silu,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_specs(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    # gemma family parameterizes RMSNorm weight as (1 + w); init zeros either way
    init = "zeros" if cfg.norm == "rmsnorm" else "ones"
    specs = {"scale": ParamSpec((d,), ("noshard",), init)}
    if cfg.norm == "layernorm":
        specs["bias"] = ParamSpec((d,), ("noshard",), "zeros")
    return specs


def norm_apply(params, x, cfg: ModelConfig):
    """RMSNorm/LayerNorm: reductions in f32, elementwise math in x.dtype.

    Keeping the big elementwise chain in bf16 (only the [..., 1] statistics
    are f32) removes ~4x f32 activation traffic per norm that dominated the
    train-step memory term (EXPERIMENTS.md §Perf hillclimb 3).
    """
    # norm weights are (D,): broadcast them explicitly so the elementwise
    # chain is rank-clean under jax_numpy_rank_promotion='raise' (the
    # --sanitize mode); reshape-then-broadcast is bit-identical
    def wide(w):
        return jnp.reshape(w.astype(x.dtype), (1,) * (x.ndim - 1) + (-1,))

    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(x.astype(f32)), axis=-1, keepdims=True)
        mult = jax.lax.rsqrt(var + cfg.norm_eps).astype(x.dtype)
        y = x * mult * wide(1.0 + params["scale"])
    else:
        xf = x.astype(f32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        mult = jax.lax.rsqrt(var + cfg.norm_eps)
        y = ((x - mu.astype(x.dtype)) * mult.astype(x.dtype)
             * wide(params["scale"]) + wide(params["bias"]))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=f32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # [D/2]
    pos = positions[..., :, None].astype(f32)       # [..., S, 1]
    # explicit rank match (rank-promotion-clean under --sanitize)
    ang = pos * jnp.reshape(inv, (1,) * (pos.ndim - 1) + (-1,))
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(f32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU) with Horn parallel-dropout hook
# ---------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    specs = {
        "wi": ParamSpec((d, ff), ("embed", "ffn")),
        "wo": ParamSpec((ff, d), ("ffn", "embed")),
    }
    if cfg.mlp_gated:
        specs["wg"] = ParamSpec((d, ff), ("embed", "ffn"))
    return specs


def mlp_apply(params, x, cfg: ModelConfig, ctx: ShardingCtx, *,
              hidden_mask=None, mask_blocks=None):
    """x: [B, S, d].  hidden_mask: [B, 1, ff]-broadcastable or None.

    ``hidden_mask`` is Horn's per-group structured neuron mask (inverted-dropout
    scaled at mask-creation time); group -> sample expansion happens upstream.
    ``mask_blocks`` ([G, ff/block] in {0, 1/keep}) enables the block-sparse
    Pallas path on TPU: dropped 128-blocks of hidden units are *skipped* in
    the up/gate matmuls (kernels/dropout_matmul) — the paper's compute-saving
    claim realized.  Semantics identical to the masked dense path.
    """
    act = ACTS[cfg.act]
    from repro.kernels.backend import get_backend
    backend = get_backend()
    if mask_blocks is not None and backend != "ref":
        from repro.kernels.dropout_matmul.kernel import dropout_matmul
        B, S, d = x.shape
        G, nb = mask_blocks.shape
        block_n = cfg.d_ff // nb
        xg = x.reshape(G, (B // G) * S, d)
        interp = backend == "interpret"
        # gate uses a {0,1} mask (masking *inside* the activation is wrong);
        # the 1/keep scale rides on the up projection.
        blocks01 = (mask_blocks > 0).astype(f32)
        if cfg.mlp_gated:
            up = dropout_matmul(xg, params["wi"], mask_blocks,
                                block_n=block_n, interpret=interp)
            gate = dropout_matmul(xg, params["wg"], blocks01,
                                  block_n=block_n, interpret=interp)
            h = act(gate) * up
        else:
            # act(up * s) != act(up) * s, so mask {0,1} first, scale after
            h = act(dropout_matmul(xg, params["wi"], blocks01,
                                   block_n=block_n, interpret=interp))
            mask = jnp.repeat(mask_blocks, block_n, axis=-1)
            h = h * mask[:, None, :]
        h = h.astype(x.dtype).reshape(B, S, cfg.d_ff)
        out = jnp.einsum("...f,fd->...d", h, params["wo"])
        return ctx.constrain(out, "batch", "seq", "act_embed")
    with jax.named_scope("mlp_block"):
        up = jnp.einsum("...d,df->...f", x, params["wi"])
        if cfg.mlp_gated:
            gate = jnp.einsum("...d,df->...f", x, params["wg"])
            h = act(gate) * up
        else:
            h = act(up)
        h = ctx.constrain(h, "batch", "seq", "act_ffn")
        if hidden_mask is not None:
            h = h * hidden_mask.astype(h.dtype)
        # row-parallel down-proj: keep the TP psum in the activation dtype
        out = jnp.einsum("...f,fd->...d", h, params["wo"],
                         preferred_element_type=x.dtype)
    return ctx.constrain(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based static-capacity dispatch)
# ---------------------------------------------------------------------------
def moe_specs(cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.moe_ff, cfg.num_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "wi": ParamSpec((e, d, ff), ("experts", "embed", "moe_ffn")),
        "wo": ParamSpec((e, ff, d), ("experts", "moe_ffn", "embed")),
    }
    if cfg.mlp_gated:
        specs["wg"] = ParamSpec((e, d, ff), ("experts", "embed", "moe_ffn"))
    return specs


def _positions_in_segment(sorted_ids, length):
    """Given row-sorted expert ids, rank of each element within its id-segment."""
    idx = jnp.arange(length)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    return idx - seg_start


def _route_row(flat_e, num_experts):
    """Per-row routing bookkeeping.  flat_e: [S*k] expert ids.

    Returns (pos_in_expert [S*k], counts [E], order [S*k]).
    """
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    pos_sorted = _positions_in_segment(flat_e[order], n)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    counts = jnp.sum(jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32), axis=0)
    return pos, counts, order


def moe_apply(params, x, cfg: ModelConfig, ctx: ShardingCtx, *, hidden_mask=None):
    """x: [..., S, d] -> [..., S, d] plus aux losses dict.

    Routing is per-sequence (GShard 'group = sequence'), sort-based:
    argsort tokens by expert, gather into a static [*, E, C, d] buffer, run the
    expert FFN as one einsum (experts sharded over `model` => EP all-to-all),
    scatter-gather back, combine with router weights.  Over-capacity tokens are
    dropped (residual passes them through); drop fraction reported in aux.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xr = x.reshape((-1,) + orig_shape[-2:])          # [R, S, d] rows
    R, S, _ = xr.shape
    E, K = cfg.num_experts, cfg.experts_per_tok
    C = -(-S * K * cfg.capacity_factor // E) if E else S   # ceil
    C = max(4, min(int(C), S * K))
    act = ACTS[cfg.act]

    logits = jnp.einsum("rsd,de->rse", xr, params["router"]).astype(f32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, K)          # [R, S, K]
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_e = gate_e.reshape(R, S * K)
    pos, counts, order = jax.vmap(partial(_route_row, num_experts=E))(flat_e)
    keep = pos < C                                     # [R, S*K]

    # --- dispatch: build [R, E, C] source-token indices from the sort order ---
    starts = jnp.cumsum(counts, axis=-1) - counts      # exclusive prefix  [R, E]
    slot_idx = starts[:, :, None] + jnp.arange(C)[None, None, :]       # [R, E, C]
    slot_valid = jnp.arange(C)[None, None, :] < jnp.minimum(counts, C)[:, :, None]
    slot_idx = jnp.clip(slot_idx, 0, S * K - 1)
    src_flat = jnp.take_along_axis(order, slot_idx.reshape(R, E * C), axis=1)
    src_tok = (src_flat // K).reshape(R, E, C)         # token index per slot

    disp = jnp.take_along_axis(xr, src_tok.reshape(R, E * C)[..., None], axis=1)
    disp = disp.reshape(R, E, C, d) * slot_valid[..., None].astype(x.dtype)
    disp = ctx.constrain(disp, "batch", "experts", None, "act_embed")

    # --- expert FFN ---
    with jax.named_scope("moe_ffn"):
        up = jnp.einsum("recd,edf->recf", disp, params["wi"])
        if cfg.mlp_gated:
            gate = jnp.einsum("recd,edf->recf", disp, params["wg"])
            h = act(gate) * up
        else:
            h = act(up)
        if hidden_mask is not None:                # Horn mask on expert hidden
            h = h * hidden_mask.astype(h.dtype)
        eout = jnp.einsum("recf,efd->recd", h, params["wo"])
    eout = ctx.constrain(eout, "batch", "experts", None, "act_embed")

    # --- combine: each (token, k) reads its slot (e, pos) if kept ---
    flat_pos = jnp.clip(pos, 0, C - 1)
    slot_of_choice = flat_e * C + flat_pos             # [R, S*K]
    gathered = jnp.take_along_axis(
        eout.reshape(R, E * C, d), slot_of_choice[..., None], axis=1)
    gathered = gathered.reshape(R, S, K, d) * keep.reshape(R, S, K, 1).astype(x.dtype)
    out = jnp.einsum("rskd,rsk->rsd", gathered, gate_w.astype(x.dtype))

    # --- aux losses / stats ---
    me = jnp.mean(jax.nn.one_hot(gate_e, E, dtype=f32), axis=(0, 1, 2))  # frac routed
    ce = jnp.mean(probs, axis=(0, 1))                                    # router mass
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(f32)),
    }
    return out.reshape(orig_shape), aux


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig):
    specs = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                                    ("vocab", "embed"), "normal", 1.0)}
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    return specs


def embed_apply(params, tokens, cfg: ModelConfig, ctx: ShardingCtx):
    x = jnp.take(params["embedding"], tokens, axis=0).astype(cfg.dtype)
    if cfg.post_sublayer_norm:   # gemma family scales embeddings
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return ctx.constrain(x, "batch", "seq", "act_embed")


def unembed_apply(params, x, cfg: ModelConfig, ctx: ShardingCtx):
    w = params.get("unembed")
    if w is None:
        w = params["embedding"].T
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return ctx.constrain(logits, "batch", "seq", "vocab")
