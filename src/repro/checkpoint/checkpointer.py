"""Checkpointing: async save, integrity-checked restore, elastic reshard.

Layout: one directory per step containing
  * ``shard_<host>.npz``  — flat {path: array} for this host's slice
  * ``meta.json``         — step, flat tree structure, per-tensor checksums,
                            mesh shape at save time, monotonic save id
  * ``_COMMITTED``        — written last; restores ignore uncommitted dirs
    (a preempted save can never corrupt a restore)

Elastic restore: arrays are saved unsharded per host slice here (single-host
container), but the restore path re-shards to ANY mesh whose axes divide the
global shapes — the state dict is re-laid-out by jax.device_put against the
new mesh's NamedShardings.  ``tests/test_checkpoint.py`` exercises
save -> mutate -> restore and checksum-detected corruption.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state, *, blocking: bool = True) -> str:
        """Snapshot to host memory synchronously, write to disk (optionally
        in a background thread — training continues while IO happens)."""
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}   # device->host now
        path = os.path.join(self.dir, f"step_{step:09d}")

        def write():
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"), **host)
            meta = {
                "step": step,
                "time": time.time(),
                "checksums": {k: _checksum(v) for k, v in host.items()},
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.available_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def available_steps(self):
        out = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, "_COMMITTED"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, like_state, *, step: Optional[int] = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``like_state``.

        ``shardings``: optional matching pytree of NamedShardings for the
        (possibly different) current mesh — this is the elastic-reshard path.
        Raises on checksum mismatch (corrupt shard) so the caller can fall
        back to an earlier step (``restore_latest_good``).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoints")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        if verify:
            for k in data.files:
                if _checksum(data[k]) != meta["checksums"][k]:
                    raise ValueError(f"checksum mismatch at {k} (step {step})")

        leaves_paths = jax.tree_util.tree_leaves_with_path(like_state)
        treedef = jax.tree_util.tree_structure(like_state)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None
                        else [None] * len(leaves_paths))
        out = []
        for (pth, like), shd in zip(leaves_paths, shard_leaves):
            key = jax.tree_util.keystr(pth)
            arr = data[key]
            if shd is not None:
                arr = jax.device_put(arr, shd)     # elastic reshard here
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), step

    def restore_latest_good(self, like_state, *, shardings=None):
        """Walk back through checkpoints until one passes verification."""
        for step in reversed(self.available_steps()):
            try:
                return self.restore(like_state, step=step,
                                    shardings=shardings, verify=True)
            except (ValueError, KeyError, OSError):
                continue
        raise FileNotFoundError("no restorable checkpoint")
