"""Optimizers (pure pytree functions): momentum SGD (the paper's optimizer)
and AdamW for the LM-scale configs.  No optax dependency by design.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

f32 = jnp.float32


# ---------------------------------------------------------------------------
# Momentum SGD — paper §3: eta = 0.3, alpha (momentum) = 0.98
# ---------------------------------------------------------------------------
def sgdm_init(params):
    return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, f32), params)}


def sgdm_update(grads, state, params, *, lr, momentum=0.98, weight_decay=0.0):
    def upd(g, m, p):
        g = g.astype(f32)
        if weight_decay:
            g = g + weight_decay * p.astype(f32)
        m_new = momentum * m + g
        p_new = p.astype(f32) - lr * m_new
        return p_new.astype(p.dtype), m_new

    flat = jax.tree.map(upd, grads, state["mom"], params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mom = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mom": new_mom}


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params):
    z = lambda p: jnp.zeros_like(p, f32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    t = state["t"] + 1
    tf = t.astype(f32)

    def upd(g, m, v, p):
        g = g.astype(f32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / (1 - b1 ** tf)
        vhat = v_new / (1 - b2 ** tf)
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(f32)
        return (p.astype(f32) - lr * step).astype(p.dtype), m_new, v_new

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    pick = lambda i: jax.tree.map(lambda t_: t_[i], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "t": t}


OPTIMIZERS = {
    "sgdm": (sgdm_init, sgdm_update),
    "adamw": (adamw_init, adamw_update),
}


def make_optimizer(name: str):
    return OPTIMIZERS[name]


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(f32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(f32) * scale).astype(x.dtype), tree), norm
