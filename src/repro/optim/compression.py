"""Gradient compression for cross-group merges: int8 quantization with
error feedback (residual carried across steps so the compression bias
vanishes over time).  Used by the explicit (shard_map) merge paths — the
pjit paths leave the all-reduce to GSPMD in bf16.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


def quantize_int8(x, axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization.

    ``axis=None`` (gradient compression): one scale for the whole tensor —
    returns (q int8, scale f32 scalar).

    ``axis`` given (a tuple of axes to reduce over): a scale per remaining
    slice, kept with ``keepdims=True`` so ``q * scale`` broadcasts back.
    The paged-KV pool uses this as the per-page-per-head variant: pools are
    [P, psize, KH, D] and ``axis=(1, 3)`` yields a [P, 1, KH, 1] scale
    (one f32 per (page, kv-head), stored beside the int8 pages).
    """
    xf = x.astype(f32)
    if axis is None:
        scale = jnp.max(jnp.abs(xf))
    else:
        scale = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(scale / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    """Inverse of ``quantize_int8``: scale must broadcast against q (scalar
    for the per-tensor variant, keepdims-shaped for the per-axis variant)."""
    return q.astype(f32) * scale


def compress_tree(tree):
    return jax.tree.map(quantize_int8, tree)


def ef_compress(grad, residual):
    """Error-feedback compress one tensor.

    Returns (q, scale, new_residual): the residual accumulates what int8
    couldn't represent and is re-added next step.
    """
    corrected = grad.astype(f32) + (residual if residual is not None else 0.0)
    q, scale = quantize_int8(corrected)
    new_residual = corrected - dequantize_int8(q, scale)
    return q, scale, new_residual


def ef_compress_tree(grads, residuals):
    """Tree version.  residuals: matching pytree of f32 (or None-init zeros)."""
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros(g.shape, f32), grads)
    out = jax.tree.map(ef_compress, grads, residuals)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1), pick(2)


def psum_mean_compressed(q_tree, scale_tree, axis_names) -> "jax.Array":
    """Inside shard_map: all-reduce int8 grads (accumulate in int32).

    Each shard contributes q*scale; scales differ per shard, so we reduce
    q (widened) and scale-weighted values separately:
      mean(g) ≈ psum(q * scale) / n — computed in f32 after widening int8->f32
    which halves the wire bytes vs bf16 because the *transferred* tensor is
    the int8 payload (XLA reduces the widened form; on TPU the compiler packs
    int8 operands — we also report the compression factor in metrics, not
    claim wire-level guarantees).
    """
    n = 1.0
    for a in (axis_names if isinstance(axis_names, tuple) else (axis_names,)):
        n = n * jax.lax.psum(1.0, a)
    def red(q, s):
        contrib = q.astype(f32) * s
        return jax.lax.psum(contrib, axis_names) / n
    return jax.tree.map(red, q_tree, scale_tree)
