"""hornlint: static-analysis passes + runtime sanitizers for the serving
stack's unwritten contracts.

Four AST pass families (see the sibling modules):

* ``retrace``          — jit recompile/retrace hazards (HL1xx)
* ``host_sync``        — host-device sync leaks in hot paths (HL2xx)
* ``pallas_contracts`` — Pallas grid/BlockSpec/index_map contracts (HL3xx)
* ``pool_lifetime``    — PagePool alloc/release pairing on all paths (HL4xx)

CLI: ``python -m repro.analysis.hornlint [paths...]``.  Findings are
diffed against a committed baseline (``analysis/baseline.json``) so CI
fails only on *new* violations.  Runtime counterpart: ``sanitize.py``
(wired behind ``serve.py --sanitize``).
"""
from repro.analysis.core import (Finding, lint_paths, lint_source,
                                 load_baseline, write_baseline)

__all__ = ["Finding", "lint_paths", "lint_source", "load_baseline",
           "write_baseline"]
