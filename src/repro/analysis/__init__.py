"""hornlint + hornshape: static analysis and runtime sanitizers for the
serving stack's unwritten contracts.

Six AST pass families (see the sibling modules):

* ``retrace``            — jit recompile/retrace hazards (HL1xx)
* ``host_sync``          — host-device sync leaks in hot paths (HL2xx)
* ``pallas_contracts``   — Pallas grid/BlockSpec/index_map contracts (HL3xx)
* ``pool_lifetime``      — PagePool alloc/release pairing on all paths (HL4xx)
* ``sharding_contracts`` — shard_map/PartitionSpec/collective contracts
  for the mesh scale-out (HL5xx)
* ``donation``           — donate_argnums use-after-donate and pallas
  input_output_aliases consistency (HL6xx)

Beyond linting, ``hornshape`` *proves*: a symbolic abstract interpreter
(``symbolic``) re-executes each kernel wrapper without importing jax,
captures every ``pallas_call``, and ``blockspec_verify`` discharges
in-bounds, exact-coverage, and aliasing obligations over all grid points
— with counterexample grid points on failure (HS0xx).

CLIs: ``python -m repro.analysis.hornlint [paths...]`` (findings diffed
against the committed ``analysis/baseline.json`` so CI fails only on
*new* violations) and ``python -m repro.analysis.hornshape [files...]``.
Runtime counterpart: ``sanitize.py`` (wired behind ``serve.py
--sanitize``), which includes the hornshape geometry twin.
"""
from repro.analysis.core import (Finding, lint_paths, lint_source,
                                 load_baseline, write_baseline)

__all__ = ["Finding", "lint_paths", "lint_source", "load_baseline",
           "write_baseline"]
