"""HL3xx — Pallas kernel contracts for every ``pl.pallas_call``.

* HL301 ``dim-semantics-rank``: ``dimension_semantics`` tuple length must
  equal the grid rank — a silent mismatch misassigns megacore partitioning.
* HL302 ``accumulator-parallel``: a grid dim that carries accumulator
  state across steps (detected from the ``pl.when(program_id(k) == 0)``
  scratch-init idiom) must be declared ``"arbitrary"`` — ``"parallel"``
  lets the compiler split the carry across cores; a kernel with carried
  scratch and *no* ``dimension_semantics`` at all gets the same finding.
* HL303 ``index-map-arity``: every ``BlockSpec``/grid-spec ``index_map``
  must take exactly grid-rank required positional args (scalar-prefetch
  ``*refs`` tails are fine; defaulted extras are closure captures).
* HL304 ``null-page-clamp``: block-table gathers inside index maps must
  clamp the page index into the table and select the null page for dead
  steps (``jnp.where(live, bt[...], 0)``) — unclamped gathers read out
  of bounds on the last partial page window (the PR 7 rule).

The pass resolves ``grid=``/``grid_spec=`` through local and module-level
constant assignments (``DIM_SEMANTICS = (...)`` style) before checking.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.core import (Finding, PassContext, dotted_name,
                                 enclosing_function_ranges, qualname_at)

RULES = {
    "HL301": "dimension_semantics length must match pallas grid rank",
    "HL302": "accumulator-carry grid dim must not be 'parallel' (declare "
             "dimension_semantics with 'arbitrary' for the carry dim)",
    "HL303": "index_map arity must match pallas grid rank",
    "HL304": "block-table gather in an index_map must clamp to the null "
             "page for dead grid steps",
}

_BT_NAMES = {"bt", "block_table", "block_tables", "btab"}


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


class _ModuleConstants:
    """name -> value AST for simple module- and function-local assigns."""

    def __init__(self, tree: ast.AST):
        self.module: Dict[str, ast.AST] = {}
        for stmt in tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                self.module[stmt.targets[0].id] = stmt.value

    @staticmethod
    def locals_of(fn: ast.AST) -> Dict[str, ast.AST]:
        out: Dict[str, ast.AST] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                out[node.targets[0].id] = node.value
        return out

    def resolve(self, node: ast.AST, local: Dict[str, ast.AST],
                depth: int = 0) -> Optional[ast.AST]:
        while isinstance(node, ast.Name) and depth < 4:
            nxt = local.get(node.id, self.module.get(node.id))
            if nxt is None:
                break               # unresolvable: keep the Name itself
            node = nxt
            depth += 1
        return node


def _tuple_len(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Tuple):
        if any(isinstance(e, ast.Starred) for e in node.elts):
            return None
        return len(node.elts)
    return None


def _required_positional(fn_args: ast.arguments) -> int:
    return len(fn_args.args) - len(fn_args.defaults)


def _program_id_dims(fn: ast.FunctionDef) -> Dict[str, int]:
    """Names bound to pl.program_id(k) anywhere in the kernel body."""
    dims: Dict[str, int] = {}
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        tgts, vals = node.targets[0], node.value
        pairs = []
        if isinstance(tgts, ast.Name):
            pairs = [(tgts, vals)]
        elif isinstance(tgts, ast.Tuple) and isinstance(vals, ast.Tuple) \
                and len(tgts.elts) == len(vals.elts):
            pairs = list(zip(tgts.elts, vals.elts))
        for t, v in pairs:
            if isinstance(t, ast.Name) and isinstance(v, ast.Call) \
                    and dotted_name(v.func).endswith("program_id") \
                    and v.args and isinstance(v.args[0], ast.Constant):
                dims[t.id] = v.args[0].value
    return dims


def _carry_dims(fn: ast.FunctionDef) -> List[int]:
    """Grid dims guarding a `== 0` init (`pl.when(p == 0)` idiom): the
    accumulator is initialized on the first step of that dim and carried
    across its steps."""
    dims = _program_id_dims(fn)
    out: List[int] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        callee = node.func
        if not dotted_name(callee).endswith("when"):
            continue
        for cond in node.args:
            for cmp in ast.walk(cond):
                if isinstance(cmp, ast.Compare) and len(cmp.ops) == 1 \
                        and isinstance(cmp.ops[0], ast.Eq):
                    sides = [cmp.left, cmp.comparators[0]]
                    const = [s for s in sides
                             if isinstance(s, ast.Constant)
                             and s.value == 0]
                    if not const:
                        continue
                    other = sides[1 - sides.index(const[0])]
                    dim = None
                    if isinstance(other, ast.Name):
                        dim = dims.get(other.id)
                    elif isinstance(other, ast.Call) \
                            and dotted_name(other.func).endswith(
                                "program_id") \
                            and other.args \
                            and isinstance(other.args[0], ast.Constant):
                        dim = other.args[0].value
                    if dim is not None and dim not in out:
                        out.append(dim)
    return out


def _resolve_kernel_fn(call: ast.Call, consts: _ModuleConstants,
                       local: Dict[str, ast.AST],
                       defs: Dict[str, ast.FunctionDef]
                       ) -> Optional[ast.FunctionDef]:
    if not call.args:
        return None
    fn = consts.resolve(call.args[0], local)
    if isinstance(fn, ast.Call):        # functools.partial(_kernel, ...)
        fn = fn.args[0] if fn.args else None
        fn = consts.resolve(fn, local) if fn is not None else None
    name = dotted_name(fn) if fn is not None else ""
    return defs.get(name.split(".")[-1]) if name else None


def _index_map_fns(call: ast.Call, grid_spec: Optional[ast.Call],
                   consts: _ModuleConstants, local: Dict[str, ast.AST],
                   defs: Dict[str, ast.FunctionDef]) -> List[ast.AST]:
    """Collect index_map callables from in_specs/out_specs/out_shape
    BlockSpecs of this pallas_call (through one level of Name/helper
    resolution)."""
    out: List[ast.AST] = []
    roots: List[ast.AST] = []
    for holder in (call, grid_spec):
        if holder is None:
            continue
        for kw_name in ("in_specs", "out_specs", "out_spec"):
            v = _kw(holder, kw_name)
            if v is not None:
                roots.append(consts.resolve(v, local) or v)
    for root in roots:
        for node in ast.walk(root):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func).endswith("BlockSpec"):
                im = None
                if _kw(node, "index_map") is not None:
                    im = _kw(node, "index_map")
                elif len(node.args) >= 2:
                    im = node.args[1]
                elif node.args:
                    # positional style BlockSpec(index_map, block_shape)
                    # vs BlockSpec(block_shape): only treat callables
                    cand = node.args[0]
                    if isinstance(cand, (ast.Lambda, ast.Name)):
                        im = cand
                if im is None:
                    continue
                im = consts.resolve(im, local)
                if isinstance(im, ast.Lambda):
                    out.append(im)
                else:
                    name = dotted_name(im) if im is not None else ""
                    if name and name.split(".")[-1] in defs:
                        out.append(defs[name.split(".")[-1]])
    return out


def _grid_info(call: ast.Call, consts: _ModuleConstants,
               local: Dict[str, ast.AST]):
    """-> (rank or None, dim_semantics tuple-node or None,
           has_semantics_kw, grid_spec call or None)."""
    grid = consts.resolve(_kw(call, "grid"), local) \
        if _kw(call, "grid") is not None else None
    grid_spec = consts.resolve(_kw(call, "grid_spec"), local) \
        if _kw(call, "grid_spec") is not None else None
    if grid is None and isinstance(grid_spec, ast.Call):
        g = _kw(grid_spec, "grid")
        grid = consts.resolve(g, local) if g is not None else None
    rank = _tuple_len(grid) if grid is not None else None

    sem_node, has_sem = None, False
    cp = _kw(call, "compiler_params")
    cp = consts.resolve(cp, local) if cp is not None else None
    if isinstance(cp, ast.Call):
        ds = _kw(cp, "dimension_semantics")
        if ds is not None:
            has_sem = True
            sem_node = consts.resolve(ds, local)
    elif isinstance(cp, ast.Dict):
        for k, v in zip(cp.keys, cp.values):
            if isinstance(k, ast.Constant) \
                    and k.value == "dimension_semantics":
                has_sem = True
                sem_node = consts.resolve(v, local)
    grid_spec_call = grid_spec if isinstance(grid_spec, ast.Call) else None
    return rank, sem_node, has_sem, grid_spec_call


def _check_null_clamp(im_fns: List[ast.AST], path: str, spans,
                      findings: List[Finding]) -> None:
    for im in im_fns:
        body_nodes = [im.body] if isinstance(im, ast.Lambda) else im.body
        clamped_lines = set()
        for root in body_nodes if isinstance(body_nodes, list) \
                else [body_nodes]:
            for node in ast.walk(root):
                if isinstance(node, ast.Call) and dotted_name(
                        node.func) in ("jnp.where", "jnp.minimum",
                                       "jnp.clip", "lax.select",
                                       "jax.lax.select"):
                    for sub in ast.walk(node):
                        clamped_lines.add(id(sub))
        for root in body_nodes if isinstance(body_nodes, list) \
                else [body_nodes]:
            for node in ast.walk(root):
                if isinstance(node, ast.Subscript) \
                        and id(node) not in clamped_lines:
                    base = node.value
                    is_bt = (isinstance(base, ast.Name)
                             and base.id in _BT_NAMES) \
                        or (isinstance(base, ast.Subscript)
                            and isinstance(base.value, ast.Name)
                            and base.value.id in ("refs", "scalar_refs"))
                    if is_bt and not isinstance(node.slice, ast.Constant):
                        findings.append(Finding(
                            "HL304", path, node.lineno, node.col_offset,
                            "block-table gather without a null-page "
                            "clamp — wrap in jnp.where(live, bt[...], 0) "
                            "so dead grid steps read page 0",
                            qualname_at(spans, node.lineno)))


def run(tree: ast.AST, src: str, path: str, ctx: PassContext) -> List[Finding]:
    if "pallas_call" not in src:
        return []
    findings: List[Finding] = []
    consts = _ModuleConstants(tree)
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)}
    spans = enclosing_function_ranges(tree)

    # map each pallas_call to its lexically-enclosing function's locals
    fn_of: Dict[int, ast.AST] = {}
    for fn in defs.values():
        for node in ast.walk(fn):
            fn_of[id(node)] = fn

    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and dotted_name(node.func).endswith("pallas_call")):
            continue
        qual = qualname_at(spans, node.lineno)
        owner = fn_of.get(id(node))
        local = _ModuleConstants.locals_of(owner) if owner is not None \
            else {}
        rank, sem_node, has_sem, grid_spec = _grid_info(node, consts, local)
        sem_len = _tuple_len(sem_node) if sem_node is not None else None
        sems = [e.value for e in sem_node.elts
                if isinstance(e, ast.Constant)] \
            if isinstance(sem_node, ast.Tuple) else None

        if ctx.enabled("HL301") and rank is not None \
                and sem_len is not None and sem_len != rank:
            findings.append(Finding(
                "HL301", path, node.lineno, node.col_offset,
                f"dimension_semantics has {sem_len} entries but the grid "
                f"has rank {rank}", qual))

        kernel = _resolve_kernel_fn(node, consts, local, defs)
        carries = _carry_dims(kernel) if kernel is not None else []
        has_scratch = _kw(node, "scratch_shapes") is not None \
            or (grid_spec is not None
                and _kw(grid_spec, "scratch_shapes") is not None)
        if ctx.enabled("HL302") and carries and has_scratch:
            if not has_sem:
                findings.append(Finding(
                    "HL302", path, node.lineno, node.col_offset,
                    f"kernel carries accumulator state across grid "
                    f"dim(s) {carries} but declares no "
                    f"dimension_semantics — the carry dim must be "
                    f"'arbitrary'", qual))
            elif sems is not None and sem_len == rank:
                for d in carries:
                    if d < len(sems) and sems[d] == "parallel":
                        findings.append(Finding(
                            "HL302", path, node.lineno, node.col_offset,
                            f"grid dim {d} carries accumulator state "
                            f"but is declared 'parallel'", qual))

        im_fns = _index_map_fns(node, grid_spec, consts, local, defs)
        if ctx.enabled("HL303") and rank is not None:
            n_prefetch = 0
            if grid_spec is not None:
                np_kw = _kw(grid_spec, "num_scalar_prefetch")
                if isinstance(np_kw, ast.Constant):
                    n_prefetch = np_kw.value or 0
            for im in im_fns:
                args = im.args
                req = _required_positional(args)
                has_var = args.vararg is not None
                ok = req == rank or (has_var and req <= rank) \
                    or (n_prefetch and req == rank + n_prefetch)
                if not ok:
                    findings.append(Finding(
                        "HL303", path, im.lineno, im.col_offset,
                        f"index_map takes {req} required positional "
                        f"args but the grid has rank {rank}",
                        qualname_at(spans, im.lineno)))
        if ctx.enabled("HL304"):
            _check_null_clamp(im_fns, path, spans, findings)
    # one helper can serve several pallas_calls — dedupe repeated checks
    seen, out = set(), []
    for f in findings:
        k = (f.rule, f.path, f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
