"""HL1xx — jit recompile/retrace hazards.

* HL101 ``import-time-jnp``: ``jnp.*`` work at module import — traces and
  may allocate on device before the program configures backends/meshes.
* HL102 ``traced-branch``: Python ``if``/``while`` on a value derived
  from a jit root's *traced* arguments.  Under trace this either raises
  (ConcretizationTypeError) or silently bakes one branch per retrace.
* HL103 ``unbucketed-shape``: an array built with a ``len(...)``-derived
  shape in a function that drives a jitted step — every distinct length
  is a fresh compile; bucket it (``pow2_bucket``) first.
* HL104 ``unstable-static-arg``: a list/dict/set literal passed as a
  keyword to a known-jitted call — unhashable (TypeError) or, via
  workarounds, a new compile cell per call site.
* HL105 ``jit-in-loop``: ``jax.jit`` invoked inside a for/while body —
  a fresh compile cell every iteration defeats the jit cache.

HL102 starts from every function handed to ``jax.jit`` in the module
(e.g. the unified step built by ``steps.make_unified_paged_step``), taints
its parameters, and follows calls into locally-resolvable and
project-importable callees.  Exemptions keep the rule quiet on the
idioms this repo deliberately uses:

* keyword-only params (the ``ensembles`` static-flag idiom) and params
  named like config (``cfg``/``ctx``/``run``/...) are static;
* ``.shape``/``.ndim``/``.dtype``/``len()``/``isinstance()`` results are
  static under trace and kill taint;
* ``is None`` / ``in`` tests are structure checks, not value branches.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import Finding, PassContext, dotted_name

RULES = {
    "HL101": "jnp work at import time (move into a function or use np)",
    "HL102": "Python branch on a traced value inside a jitted callable",
    "HL103": "len()-derived array shape fed to a jitted step (bucket it)",
    "HL104": "unhashable container literal passed as a static arg to a "
             "jitted call",
    "HL105": "jax.jit called inside a loop body (new compile cell per "
             "iteration)",
}

STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "ctx", "run", "ecfg",
                      "mesh", "spec", "interpret"}
_STATIC_BUILTINS = {"len", "isinstance", "type", "range", "enumerate", "zip",
                    "min", "max", "sorted", "tuple", "list", "dict", "int",
                    "float", "bool", "str", "getattr", "hasattr", "divmod",
                    "abs", "sum", "round"}
_TAINT_KILL_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
_MAX_DEPTH = 8

# module-path AST cache for cross-module reachability (CLI lifetime)
_MODULE_CACHE: Dict[Path, Tuple[ast.AST, str]] = {}


# --------------------------------------------------------------------------
# module model: defs, imports, jit roots
# --------------------------------------------------------------------------
class _Module:
    def __init__(self, tree: ast.AST, path: str, file_dir: Optional[Path]):
        self.tree = tree
        self.path = path
        self.file_dir = file_dir
        self.defs: Dict[str, ast.FunctionDef] = {}
        self.imports: Dict[str, str] = {}   # local name -> dotted module
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                self.defs.setdefault(node.name, node)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def jit_roots(self) -> List[ast.FunctionDef]:
        roots: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func) == "jax.jit" and node.args:
                fn = node.args[0]
                # jax.jit(f) or jax.jit(partial(f, ...))
                if isinstance(fn, ast.Call) and fn.args:
                    fn = fn.args[0]
                name = dotted_name(fn)
                if name:
                    roots.add(name.split(".")[-1])
            elif isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    d = dec.func if isinstance(dec, ast.Call) else dec
                    if dotted_name(d) in ("jax.jit", "jit"):
                        roots.add(node.name)
        return [self.defs[n] for n in sorted(roots) if n in self.defs]

    def resolve_module(self, dotted: str) -> Optional["_Module"]:
        """Best-effort load of a project module for call-graph descent."""
        if self.file_dir is None:
            return None
        parts = dotted.split(".")
        for base in (self.file_dir, *list(self.file_dir.parents)[:6]):
            cand = base.joinpath(*parts).with_suffix(".py")
            if cand.is_file():
                if cand not in _MODULE_CACHE:
                    try:
                        _MODULE_CACHE[cand] = (ast.parse(cand.read_text()),
                                               str(cand))
                    except (OSError, SyntaxError):
                        return None
                tree, p = _MODULE_CACHE[cand]
                return _Module(tree, p, cand.parent)
        return None


# --------------------------------------------------------------------------
# HL102 taint walker
# --------------------------------------------------------------------------
class _BranchTaint:
    def __init__(self, module: _Module, findings: List[Finding]):
        self.module = module
        self.findings = findings
        self.visited: Set[Tuple[str, str, frozenset]] = set()

    # -- expression taint, given the live tainted-name set ------------
    def tainted_expr(self, node: ast.AST, env: Set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in env
        if isinstance(node, ast.Attribute):
            if node.attr in _TAINT_KILL_ATTRS:
                return False
            return self.tainted_expr(node.value, env)
        if isinstance(node, ast.Subscript):
            return self.tainted_expr(node.value, env)
        if isinstance(node, ast.BinOp):
            return (self.tainted_expr(node.left, env)
                    or self.tainted_expr(node.right, env))
        if isinstance(node, ast.UnaryOp):
            return self.tainted_expr(node.operand, env)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted_expr(v, env) for v in node.values)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in node.ops):
                return False                    # structure test, static
            return (self.tainted_expr(node.left, env)
                    or any(self.tainted_expr(c, env)
                           for c in node.comparators))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.tainted_expr(e, env) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self.tainted_expr(node.body, env)
                    or self.tainted_expr(node.orelse, env))
        if isinstance(node, ast.Starred):
            return self.tainted_expr(node.value, env)
        if isinstance(node, ast.Call):
            return self.call_result_tainted(node, env)
        return False

    def call_result_tainted(self, call: ast.Call, env: Set[str]) -> bool:
        name = dotted_name(call.func)
        if name in _STATIC_BUILTINS:
            return False
        args_tainted = any(
            self.tainted_expr(a, env)
            for a in list(call.args) + [k.value for k in call.keywords])
        # method call on a traced value (x.sum(), x.astype(...)): traced
        if isinstance(call.func, ast.Attribute) \
                and self.tainted_expr(call.func.value, env):
            return True
        if name.startswith(("jnp.", "jax.")):
            return args_tainted or name.startswith("jax.random.")
        target = self._resolve_callee(name)
        if target is not None:
            mod, fn = target
            binding = self._bind_args(fn, call, env)
            return self._summarize(mod, fn, binding, depth=0,
                                   collect=False)
        return False    # unresolved: assume host helper, keep precision

    # -- callee resolution --------------------------------------------
    def _resolve_callee(self, name: str):
        if not name:
            return None
        head, *rest = name.split(".")
        if not rest and head in self.module.defs:
            return (self.module, self.module.defs[head])
        if head in self.module.imports:
            dotted = self.module.imports[head]
            if rest:                        # api.paged_step
                mod = self.module.resolve_module(dotted)
                if mod and rest[0] in mod.defs:
                    return (mod, mod.defs[rest[0]])
            else:                           # from mod import paged_step
                owner, _, fn = dotted.rpartition(".")
                mod = self.module.resolve_module(owner) if owner else None
                if mod and fn in mod.defs:
                    return (mod, mod.defs[fn])
        return None

    def _bind_args(self, fn: ast.FunctionDef, call: ast.Call,
                   env: Set[str]) -> Set[str]:
        params = [a.arg for a in fn.args.args]
        tainted: Set[str] = set()
        for i, a in enumerate(call.args):
            if isinstance(a, ast.Starred):
                break
            if i < len(params) and self.tainted_expr(a, env):
                tainted.add(params[i])
        kw_ok = set(params) | {a.arg for a in fn.args.kwonlyargs}
        for k in call.keywords:
            if k.arg and k.arg in kw_ok and self.tainted_expr(k.value, env):
                tainted.add(k.arg)
        return {p for p in tainted if p not in STATIC_PARAM_NAMES}

    # -- function analysis --------------------------------------------
    def _summarize(self, mod: _Module, fn: ast.FunctionDef,
                   tainted_params: Set[str], depth: int,
                   collect: bool) -> bool:
        """Walk fn with the given taint; optionally emit findings.
        Returns whether any return value is tainted."""
        key = (mod.path, fn.name, frozenset(tainted_params))
        if depth > _MAX_DEPTH or key in self.visited:
            return False
        self.visited.add(key)
        env = set(tainted_params)
        returns_tainted = [False]
        self._walk_body(mod, fn, fn.body, env, depth, collect,
                        returns_tainted)
        return returns_tainted[0]

    def analyze_root(self, fn: ast.FunctionDef) -> None:
        env = {a.arg for a in fn.args.args
               if a.arg not in STATIC_PARAM_NAMES}
        self._summarize(self.module, fn, env, depth=0, collect=True)

    def _walk_body(self, mod, fn, body, env, depth, collect,
                   returns_tainted) -> None:
        for stmt in body:
            self._walk_stmt(mod, fn, stmt, env, depth, collect,
                            returns_tainted)

    def _flag(self, mod: _Module, node: ast.AST, fn_name: str,
              kind: str) -> None:
        self.findings.append(Finding(
            "HL102", mod.path, node.lineno, node.col_offset,
            f"{kind} depends on a traced value — retraces (or raises) "
            f"under jit", fn_name))

    def _walk_stmt(self, mod, fn, stmt, env, depth, collect,
                   returns_tainted) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.Return):
            if stmt.value is not None \
                    and self.tainted_expr(stmt.value, env):
                returns_tainted[0] = True
            self._descend_calls(mod, stmt, env, depth, collect)
            return
        if isinstance(stmt, ast.Assign):
            self._descend_calls(mod, stmt.value, env, depth, collect)
            t = self.tainted_expr(stmt.value, env)
            for tgt in stmt.targets:
                self._bind_target(tgt, t, env, stmt.value)
            return
        if isinstance(stmt, ast.AugAssign):
            if self.tainted_expr(stmt.value, env) \
                    and isinstance(stmt.target, ast.Name):
                env.add(stmt.target.id)
            return
        if isinstance(stmt, ast.If):
            if collect and self.tainted_expr(stmt.test, env):
                self._flag(mod, stmt.test, fn.name, "if-condition")
            self._descend_calls(mod, stmt.test, env, depth, collect)
            # union of branch effects (may-taint)
            env_else = set(env)
            self._walk_body(mod, fn, stmt.body, env, depth, collect,
                            returns_tainted)
            self._walk_body(mod, fn, stmt.orelse, env_else, depth,
                            collect, returns_tainted)
            env |= env_else
            return
        if isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.While):
                if collect and self.tainted_expr(stmt.test, env):
                    self._flag(mod, stmt.test, fn.name, "while-condition")
            else:
                if collect and self.tainted_expr(stmt.iter, env):
                    self._flag(mod, stmt.iter, fn.name, "loop iterable")
                self._bind_target(stmt.target,
                                  self.tainted_expr(stmt.iter, env), env,
                                  None)
            for _ in range(2):      # fixpoint-ish for loop-carried taint
                self._walk_body(mod, fn, stmt.body, env, depth, collect,
                                returns_tainted)
            self._walk_body(mod, fn, stmt.orelse, env, depth, collect,
                            returns_tainted)
            return
        if isinstance(stmt, (ast.With,)):
            self._walk_body(mod, fn, stmt.body, env, depth, collect,
                            returns_tainted)
            return
        if isinstance(stmt, ast.Try):
            self._walk_body(mod, fn, stmt.body, env, depth, collect,
                            returns_tainted)
            for h in stmt.handlers:
                self._walk_body(mod, fn, h.body, env, depth, collect,
                                returns_tainted)
            self._walk_body(mod, fn, stmt.finalbody, env, depth, collect,
                            returns_tainted)
            return
        if isinstance(stmt, ast.Expr):
            self._descend_calls(mod, stmt.value, env, depth, collect)
            return
        # Assert/Raise/Pass/etc: no binding effects we model

    def _bind_target(self, tgt, tainted, env, value) -> None:
        if isinstance(tgt, ast.Name):
            (env.add if tainted else env.discard)(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) \
                    and len(value.elts) == len(tgt.elts):
                for e, v in zip(tgt.elts, value.elts):
                    self._bind_target(e, self.tainted_expr(v, env), env, v)
            else:
                for e in tgt.elts:
                    self._bind_target(e, tainted, env, None)
        elif isinstance(tgt, ast.Starred):
            self._bind_target(tgt.value, tainted, env, None)

    def _descend_calls(self, mod, node, env, depth, collect) -> None:
        """Follow calls with tainted args into resolvable callees and
        lint their bodies too (findings attributed to the callee)."""
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            name = dotted_name(call.func)
            target = self._resolve_callee(name)
            if target is None:
                continue
            callee_mod, callee = target
            binding = self._bind_args(callee, call, env)
            if binding:
                # temporarily retarget resolution to the callee's module
                saved = self.module
                self.module = callee_mod
                try:
                    self._summarize(callee_mod, callee, binding,
                                    depth + 1, collect)
                finally:
                    self.module = saved


# --------------------------------------------------------------------------
# simpler rules
# --------------------------------------------------------------------------
_IMPORT_TIME_ALLOW = {"jnp.dtype", "jnp.finfo", "jnp.iinfo"}


def _import_time_jnp(tree, path, findings) -> None:
    def walk_top(stmts):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, ast.If):
                walk_top(stmt.body), walk_top(stmt.orelse)
                continue
            if isinstance(stmt, ast.Try):
                walk_top(stmt.body)
                for h in stmt.handlers:
                    walk_top(h.body)
                walk_top(stmt.finalbody)
                continue
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                    if name.startswith(("jnp.", "jax.numpy.")) \
                            and name not in _IMPORT_TIME_ALLOW:
                        findings.append(Finding(
                            "HL101", path, node.lineno, node.col_offset,
                            f"{name}() at import time traces/allocates "
                            f"before backends are configured"))
    walk_top(tree.body)


_CONSTRUCTORS = {"np.zeros", "np.ones", "np.empty", "np.full",
                 "jnp.zeros", "jnp.ones", "jnp.empty", "jnp.full"}


def _has_device_step_call(fn: ast.AST) -> bool:
    from repro.analysis.host_sync import (CURRIED_STEP_ATTRS,
                                          DEVICE_CALL_ATTRS)
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in DEVICE_CALL_ATTRS:
                return True
            if isinstance(node.func, ast.Call) \
                    and isinstance(node.func.func, ast.Attribute) \
                    and node.func.func.attr in CURRIED_STEP_ATTRS:
                return True
    return False


def _unbucketed_shapes(tree, path, findings, quals) -> None:
    for fn, qual in quals.items():
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _has_device_step_call(fn):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func) in _CONSTRUCTORS \
                    and node.args:
                shape = node.args[0]
                for sub in ast.walk(shape):
                    if isinstance(sub, ast.Call) \
                            and dotted_name(sub.func) == "len":
                        findings.append(Finding(
                            "HL103", path, node.lineno, node.col_offset,
                            "len()-derived shape feeds a jitted step: "
                            "every distinct length recompiles — bucket "
                            "it (pow2_bucket) first", qual))
                        break


def _unstable_static_args(tree, path, findings, quals, spans) -> None:
    from repro.analysis.core import qualname_at
    from repro.analysis.host_sync import (CURRIED_STEP_ATTRS,
                                          DEVICE_CALL_ATTRS)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        jitted = (isinstance(node.func, ast.Attribute)
                  and node.func.attr in DEVICE_CALL_ATTRS) \
            or (isinstance(node.func, ast.Call)
                and isinstance(node.func.func, ast.Attribute)
                and node.func.func.attr in CURRIED_STEP_ATTRS)
        if not jitted:
            continue
        for kw in node.keywords:
            if isinstance(kw.value, (ast.List, ast.Dict, ast.Set,
                                     ast.ListComp, ast.SetComp,
                                     ast.DictComp)):
                findings.append(Finding(
                    "HL104", path, kw.value.lineno, kw.value.col_offset,
                    f"container literal for static kwarg "
                    f"'{kw.arg}' — unhashable under jit; pass a tuple "
                    f"or a hashable flag", qualname_at(spans, node.lineno)))


def _jit_in_loop(tree, path, findings, spans) -> None:
    from repro.analysis.core import qualname_at

    def scan(body, in_loop):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                scan(stmt.body, False)
                continue
            is_loop = isinstance(stmt, (ast.For, ast.While))
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    break
                if (in_loop or is_loop) and isinstance(node, ast.Call) \
                        and dotted_name(node.func) == "jax.jit":
                    findings.append(Finding(
                        "HL105", path, node.lineno, node.col_offset,
                        "jax.jit inside a loop creates a fresh compile "
                        "cell per iteration — hoist and cache it",
                        qualname_at(spans, node.lineno)))
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if sub:
                    scan(sub, in_loop or is_loop)
            for h in getattr(stmt, "handlers", ()):
                scan(h.body, in_loop or is_loop)

    scan(tree.body, False)


def run(tree: ast.AST, src: str, path: str, ctx: PassContext) -> List[Finding]:
    from repro.analysis.core import enclosing_function_ranges, qualname_map
    findings: List[Finding] = []
    quals = qualname_map(tree)
    spans = enclosing_function_ranges(tree)
    if ctx.enabled("HL101"):
        _import_time_jnp(tree, path, findings)
    if ctx.enabled("HL102"):
        file_dir = None
        p = Path(path)
        if p.is_absolute() and p.is_file():
            file_dir = p.parent
        elif (ctx.root / p).is_file():
            file_dir = (ctx.root / p).parent
        module = _Module(tree, path, file_dir)
        bt = _BranchTaint(module, findings)
        for root_fn in module.jit_roots():
            bt.analyze_root(root_fn)
    if ctx.enabled("HL103"):
        _unbucketed_shapes(tree, path, findings, quals)
    if ctx.enabled("HL104"):
        _unstable_static_args(tree, path, findings, quals, spans)
    if ctx.enabled("HL105"):
        _jit_in_loop(tree, path, findings, spans)
    # interprocedural descent can visit the same callee from two roots
    seen, out = set(), []
    for f in findings:
        k = (f.rule, f.path, f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
