"""Runtime sanitizers: the dynamic counterpart of the hornlint passes.

``serve.py --sanitize`` wires three layers:

* jax guards — ``jax_debug_nans`` (any NaN produced inside the jitted
  step raises at the op that made it) and strict rank promotion
  (silent broadcasts across mismatched ranks become errors);
* per-tick pool invariants — the ``live_table_pages() == used_pages``
  accounting identity (the static pool-lifetime pass's claim, now
  checked on the real pool every tick, draft pool included) plus the
  pool's own ``check_invariants()`` refcount/free-list audit;
* block-table mirror consistency — every running slot's row version
  matches the pool's table version (a stale mirror serves garbage
  pages silently);
* hornshape geometry twin (first checked tick only) — re-verifies the
  paged-attention BlockSpec/grid obligations at the *engine's actual*
  serving geometry and cross-checks the symbolic verdicts against
  brute-force grid enumeration, so a divergence between the static
  prover and the shipped kernel surfaces in the same alert stream.

Alerts are collected, not raised: a sanitized replay run reports all
violations at exit (serve.py exits 3 if any fired), so one bad tick
doesn't hide the next.  Overhead is pure-host bookkeeping and is
excluded from bench gates — CI runs the sanitizer on a short replay
smoke, never inside a timed phase.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class InvariantAlert:
    tick: int
    kind: str
    message: str

    def render(self) -> str:
        return f"tick {self.tick}: [{self.kind}] {self.message}"


@dataclass
class Sanitizer:
    """Attachable per-tick invariant checker for a serving Engine."""
    check_every: int = 1
    alerts: List[InvariantAlert] = field(default_factory=list)
    ticks_checked: int = 0

    # ------------------------------------------------------------------
    @staticmethod
    def install_jax_guards(rank_promotion: str = "raise") -> None:
        """Global jax config: NaN tracing + strict rank promotion.
        Call *before* the engine jits anything."""
        import jax
        jax.config.update("jax_debug_nans", True)
        jax.config.update("jax_numpy_rank_promotion", rank_promotion)

    def attach(self, engine) -> "Sanitizer":
        """Wrap ``engine.step`` so every tick runs the invariant suite.
        The wrapper lives on the instance, so both the live loop and
        trace replay (which drive ``engine.step``) are covered."""
        inner: Callable = engine.step

        def stepped(*a, **kw):
            out = inner(*a, **kw)
            if engine.steps % max(1, self.check_every) == 0:
                self.check(engine, engine.steps)
            return out

        engine.step = stepped
        engine._sanitizer = self
        return self

    # ------------------------------------------------------------------
    def _alert(self, tick: int, kind: str, message: str) -> None:
        self.alerts.append(InvariantAlert(tick, kind, message))

    def check(self, engine, tick: int) -> None:
        self.ticks_checked += 1
        if self.ticks_checked == 1:
            self._check_kernel_geometry(engine, tick)
        self._check_pool(engine.pool, tick, "pool")
        spec = getattr(engine, "spec", None)
        if spec is not None:
            self._check_pool(spec.pool, tick, "draft-pool")
        self._check_block_tables(engine, tick)

    def _check_kernel_geometry(self, engine, tick: int) -> None:
        """hornshape runtime twin: symbolically re-verify paged attention
        at the geometry this engine actually serves, and cross-check the
        symbolic verdicts against brute-force grid enumeration.  Geometry
        is static per engine, so once per attach is enough."""
        ecfg = getattr(engine, "ecfg", None)
        cfg = getattr(engine, "cfg", None)
        bt = getattr(engine, "_bt", None)
        if ecfg is None or cfg is None or getattr(bt, "host", None) is None:
            return                    # not a paged engine (stubs, tests)
        try:
            from repro.analysis.hornshape import crosscheck_paged_geometry
            batch, max_pages = bt.host.shape
            alerts = crosscheck_paged_geometry(
                batch=int(batch), kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim, page_size=ecfg.page_size,
                num_pages=ecfg.num_pages, max_pages=int(max_pages),
                pages_per_step=ecfg.pages_per_step,
                quantized=str(ecfg.kv_dtype) == "int8")
        except Exception as e:        # never let the twin kill a tick
            self._alert(tick, "hornshape",
                        f"geometry cross-check failed: {e}")
            return
        for a in alerts:
            self._alert(tick, "hornshape", a)

    def _check_pool(self, pool, tick: int, label: str) -> None:
        live, used = pool.live_table_pages(), pool.used_pages
        if live != used:
            self._alert(tick, f"{label}-leak",
                        f"live_table_pages()={live} != used_pages={used} "
                        f"(free={pool.free_pages}, "
                        f"cached={pool.cached_pages}) — pages left the "
                        f"free list that no live table references")
        try:
            pool.check_invariants()
        except AssertionError as e:
            self._alert(tick, f"{label}-invariant", str(e))

    def _check_block_tables(self, engine, tick: int) -> None:
        bt = getattr(engine, "_bt", None)
        if bt is None or not hasattr(bt, "_state"):
            return
        for slot, req in engine.sched.running.items():
            try:
                want = engine.pool.table_version(req.id)
            except KeyError:
                self._alert(tick, "block-table",
                            f"slot {slot} runs seq {req.id} with no pool "
                            f"table")
                continue
            have = bt._state[slot] if slot < len(bt._state) else None
            if have is not None and have[0] == req.id \
                    and have[-1] != want:
                self._alert(tick, "block-table",
                            f"slot {slot} mirror row is stale "
                            f"(version {have[-1]} != pool version {want})")

    # ------------------------------------------------------------------
    def report(self) -> dict:
        return {
            "ticks_checked": self.ticks_checked,
            "alerts": len(self.alerts),
            "by_kind": {k: sum(1 for a in self.alerts if a.kind == k)
                        for k in sorted({a.kind for a in self.alerts})},
        }

    def render_report(self) -> str:
        if not self.alerts:
            return (f"sanitizer: 0 invariant alerts over "
                    f"{self.ticks_checked} checked ticks")
        lines = [f"sanitizer: {len(self.alerts)} invariant alert(s) over "
                 f"{self.ticks_checked} checked ticks"]
        lines += [f"  {a.render()}" for a in self.alerts[:20]]
        if len(self.alerts) > 20:
            lines.append(f"  ... and {len(self.alerts) - 20} more")
        return "\n".join(lines)
