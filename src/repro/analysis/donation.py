"""HL6xx — buffer donation and aliasing contracts.

``donate_argnums`` invalidates the caller's array: reading it after the
jitted call returns garbage (or raises under ``jax_debug_nans``-style
checks only sometimes).  The serving loop donates KV caches and decode
state on every tick, so a stale read is silent corruption.  Statically:

* HL601 ``use-after-donate``: a name passed at a donated position of a
  tracked jitted callable is *poisoned*; any later load of it in the same
  function flags — unless it is first rebound (the ``state = step(state)``
  idiom is clean) or only metadata (``.shape``/``.dtype``/``.ndim``/
  ``.size``) is read.
* HL602 ``double-donate``: a poisoned name passed again to any tracked
  donating callable (the second call receives an invalidated buffer).
* HL603 ``pallas-alias-bounds``: a literal ``input_output_aliases`` dict
  on a ``pallas_call`` must map in-range input indices to in-range output
  indices, and aliased operands with literal block shapes must agree
  (the runtime twin in hornshape checks dtype/shape on the captured
  geometry; this rule catches the statically-obvious cases without
  importing jax).

Tracked donating callables, per module: ``name = jax.jit(fn,
donate_argnums=...)`` bindings and functions decorated with
``partial(jax.jit, donate_argnums=...)``.  Calls through other paths
(returned jitted fns, dict lookups) are out of intraprocedural reach and
ignored.  Branches are merged as a union; loop bodies are scanned twice
so a donation in iteration one poisons a read in iteration two.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, PassContext, dotted_name,
                                 enclosing_function_ranges, qualname_at)

RULES = {
    "HL601": "donated buffer must not be read after the donating call",
    "HL602": "donated buffer must not be re-passed to a donating call",
    "HL603": "pallas input_output_aliases must reference valid, "
             "consistent operands",
}

_META_ATTRS = {"shape", "dtype", "ndim", "size"}


def _donated_indices(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Literal donate_argnums from a jax.jit(...) call, else None."""
    for k in call.keywords:
        if k.arg != "donate_argnums":
            continue
        v = k.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts):
            return tuple(e.value for e in v.elts)
        return None
    return None


def _is_jit(call: ast.Call) -> bool:
    return dotted_name(call.func).split(".")[-1] == "jit"


def _donating_bindings(scope: ast.AST) -> Dict[str, Tuple[int, ...]]:
    """name -> donated positional indices, for names in ``scope`` bound to
    a donating ``jax.jit`` result or defined under a donating decorator."""
    out: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and _is_jit(node.value):
            idx = _donated_indices(node.value)
            if idx:
                out[node.targets[0].id] = idx
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    inner_jit = _is_jit(dec) or any(
                        isinstance(a, (ast.Name, ast.Attribute))
                        and dotted_name(a).split(".")[-1] == "jit"
                        for a in dec.args)
                    if inner_jit:
                        idx = _donated_indices(dec)
                        if idx:
                            out[node.name] = idx
    return out


class _FlowChecker:
    """Statement-order scan of one function body tracking poisoned names."""

    def __init__(self, donors: Dict[str, Tuple[int, ...]], path: str,
                 spans, ctx: PassContext):
        self.donors = donors
        self.path = path
        self.spans = spans
        self.ctx = ctx
        self.findings: List[Finding] = []
        self.poisoned: Set[str] = set()
        self._reported: Set[Tuple[str, int, str]] = set()

    def _flag(self, rule: str, node: ast.AST, msg: str):
        key = (rule, node.lineno, msg)
        if key in self._reported or not self.ctx.enabled(rule):
            return
        self._reported.add(key)
        self.findings.append(Finding(
            rule, self.path, node.lineno, node.col_offset, msg,
            qualname_at(self.spans, node.lineno)))

    # -- expression scan ----------------------------------------------
    def _scan_expr(self, node: ast.AST):
        """Flag poisoned loads and apply donations, left to right."""
        if node is None:
            return
        donating_calls: List[ast.Call] = []
        donor_args: Set[int] = set()          # id() of Name nodes at calls
        meta_loads: Set[int] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id in self.donors:
                donating_calls.append(sub)
                for a in sub.args:
                    if isinstance(a, ast.Name):
                        donor_args.add(id(a))
            if isinstance(sub, ast.Attribute) and sub.attr in _META_ATTRS \
                    and isinstance(sub.value, ast.Name):
                meta_loads.add(id(sub.value))
        # 1. poisoned names re-passed to a donating call → HL602
        for call in donating_calls:
            for a in call.args:
                if isinstance(a, ast.Name) and a.id in self.poisoned:
                    self._flag("HL602", a,
                               f"{a.id!r} was already donated and is "
                               f"passed again to donating "
                               f"{call.func.id}()")
        # 2. any other load of a poisoned name → HL601
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                    and sub.id in self.poisoned \
                    and id(sub) not in donor_args \
                    and id(sub) not in meta_loads:
                self._flag("HL601", sub,
                           f"{sub.id!r} is read after being donated "
                           f"(donate_argnums invalidates the buffer)")
        # 3. the calls donate their argument names
        for call in donating_calls:
            for i in self.donors[call.func.id]:
                if i < len(call.args) and isinstance(call.args[i], ast.Name):
                    self.poisoned.add(call.args[i].id)

    # -- statement walk -----------------------------------------------
    def _bind(self, target: ast.AST):
        if isinstance(target, ast.Name):
            self.poisoned.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e)
        elif isinstance(target, ast.Starred):
            self._bind(target.value)

    def run_body(self, body: List[ast.stmt]):
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            self._scan_expr(stmt.value)
            for t in stmt.targets:
                self._bind(t)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            self._scan_expr(stmt.value)
            if isinstance(stmt, ast.AnnAssign):
                self._bind(stmt.target)
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                self._bind(t)
        elif isinstance(stmt, (ast.Expr, ast.Return)):
            self._scan_expr(stmt.value)
        elif isinstance(stmt, ast.If):
            self._scan_expr(stmt.test)
            before = set(self.poisoned)
            self.run_body(stmt.body)
            after_body = set(self.poisoned)
            self.poisoned = set(before)
            self.run_body(stmt.orelse)
            self.poisoned |= after_body
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter)
            for _ in range(2):       # second pass: cross-iteration reads
                self._bind(stmt.target)
                self.run_body(stmt.body)
            self.run_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            for _ in range(2):
                self._scan_expr(stmt.test)
                self.run_body(stmt.body)
            self.run_body(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars)
            self.run_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run_body(stmt.body)
            for h in stmt.handlers:
                self.run_body(h.body)
            self.run_body(stmt.orelse)
            self.run_body(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            pass                     # nested scopes are checked separately
        elif isinstance(stmt, ast.Raise):
            self._scan_expr(stmt.exc)
        elif isinstance(stmt, ast.Assert):
            self._scan_expr(stmt.test)


def _tuple_len(node: ast.AST) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _check_pallas_aliases(tree: ast.AST, path: str, spans,
                          ctx: PassContext) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and dotted_name(node.func).split(".")[-1] == "pallas_call"):
            continue
        kws = {k.arg: k.value for k in node.keywords}
        aliases = kws.get("input_output_aliases")
        if not isinstance(aliases, ast.Dict):
            continue
        pairs: List[Tuple[int, int, ast.AST]] = []
        for k, v in zip(aliases.keys, aliases.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, int) \
                    and isinstance(v, ast.Constant) \
                    and isinstance(v.value, int):
                pairs.append((k.value, v.value, k))
        if not pairs:
            continue
        n_in = _tuple_len(kws.get("in_specs"))
        n_out = _tuple_len(kws.get("out_shape"))
        if n_out is None and "out_shape" in kws:
            n_out = 1                # single ShapeDtypeStruct
        qual = qualname_at(spans, node.lineno)
        for i, o, knode in pairs:
            if i < 0 or o < 0 or (n_in is not None and i >= n_in) \
                    or (n_out is not None and o >= n_out):
                findings.append(Finding(
                    "HL603", path, knode.lineno, knode.col_offset,
                    f"input_output_aliases {{{i}: {o}}} is out of range "
                    f"(inputs={n_in}, outputs={n_out})", qual))
                continue
            in_specs = kws.get("in_specs")
            out_specs = kws.get("out_specs")
            in_bs = _blockspec_shape(in_specs.elts[i]) \
                if isinstance(in_specs, (ast.Tuple, ast.List)) else None
            if isinstance(out_specs, (ast.Tuple, ast.List)) \
                    and o < len(out_specs.elts):
                out_bs = _blockspec_shape(out_specs.elts[o])
            elif out_specs is not None and o == 0:
                out_bs = _blockspec_shape(out_specs)
            else:
                out_bs = None
            if in_bs is not None and out_bs is not None and in_bs != out_bs:
                findings.append(Finding(
                    "HL603", path, knode.lineno, knode.col_offset,
                    f"input_output_aliases {{{i}: {o}}} aliases operands "
                    f"with different block shapes {in_bs} vs {out_bs}",
                    qual))
    return findings


def _blockspec_shape(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if not (isinstance(node, ast.Call)
            and dotted_name(node.func).split(".")[-1] == "BlockSpec"
            and node.args):
        return None
    shp = node.args[0]
    if isinstance(shp, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, int)
            for e in shp.elts):
        return tuple(e.value for e in shp.elts)
    return None


def run(tree: ast.AST, src: str, path: str, ctx: PassContext) -> List[Finding]:
    if "donate_argnums" not in src and "input_output_aliases" not in src:
        return []
    findings: List[Finding] = []
    spans = enclosing_function_ranges(tree)

    if ctx.enabled("HL601") or ctx.enabled("HL602"):
        module_donors = _donating_bindings(tree)
        scopes: List[Tuple[ast.AST, List[ast.stmt]]] = [(tree, tree.body)]
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node, node.body))
        for scope, body in scopes:
            donors = dict(module_donors) if scope is not tree else \
                module_donors
            if scope is not tree:
                donors.update(_donating_bindings(scope))
            if not donors:
                continue
            checker = _FlowChecker(donors, path, spans, ctx)
            checker.run_body(body)
            findings.extend(checker.findings)

    if ctx.enabled("HL603") and "input_output_aliases" in src:
        findings.extend(_check_pallas_aliases(tree, path, spans, ctx))

    # scopes nest, so the same statement can be scanned in both the module
    # scope and its enclosing function — dedupe on (rule, line, message)
    seen = set()
    out = []
    for f in findings:
        key = (f.rule, f.line, f.col, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out
