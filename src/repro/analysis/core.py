"""hornlint core: findings, suppression comments, baselines, file walking.

A *pass* is a module exposing ``RULES`` (rule id -> one-line description)
and ``run(tree, src, path, ctx) -> list[Finding]``.  Passes are pure AST
analyses — nothing here imports jax, so the linter runs anywhere.

Suppression comments (matched per physical line):

* ``# hornlint: sync-ok``        — suppresses HL2xx (host-sync) findings
  on that line; the annotation for *deliberate* tick-forcing syncs.
* ``# hornlint: ignore``         — suppresses every rule on that line.
* ``# hornlint: ignore[HLnnn]``  — suppresses the listed rules only.
* ``# hornlint: hot-path``       — on a ``def`` line: opt the function in
  to host-sync analysis (in addition to the built-in hot-scope list).

Baselines: a committed JSON file of finding fingerprints.  The CLI exits
nonzero only for findings whose fingerprint is absent from the baseline,
so pre-existing debt is tracked without blocking CI, and fixed entries
are reported so the baseline can be re-tightened.
"""
from __future__ import annotations

import ast
import hashlib
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

_SUPPRESS_RE = re.compile(
    r"#\s*hornlint:\s*(sync-ok|ignore(?:\[(?P<rules>[^\]]+)\])?|hot-path)")

SYNC_FAMILY_PREFIX = "HL2"


@dataclass(frozen=True)
class Finding:
    rule: str           # e.g. "HL201"
    path: str           # repo-relative posix path
    line: int
    col: int
    message: str
    qualname: str = ""  # enclosing function qualname, "" at module level

    @property
    def fingerprint(self) -> str:
        # Deliberately line-number-free so unrelated edits above a known
        # finding don't churn the baseline; qualname + message pin it.
        raw = "|".join((self.rule, self.path, self.qualname, self.message))
        return hashlib.blake2b(raw.encode(), digest_size=8).hexdigest()

    def render(self) -> str:
        where = f"{self.path}:{self.line}:{self.col}"
        fn = f" [{self.qualname}]" if self.qualname else ""
        return f"{where}: {self.rule}{fn} {self.message}"


class Suppressions:
    """Per-file map of line -> suppression kind parsed from comments."""

    def __init__(self, src: str):
        self.sync_ok: set = set()
        self.ignore_all: set = set()
        self.ignore_rules: Dict[int, set] = {}
        self.hot_path: set = set()
        for i, text in enumerate(src.splitlines(), start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            kind = m.group(1)
            if kind == "sync-ok":
                self.sync_ok.add(i)
            elif kind == "hot-path":
                self.hot_path.add(i)
            elif kind.startswith("ignore"):
                rules = m.group("rules")
                if rules:
                    self.ignore_rules.setdefault(i, set()).update(
                        r.strip() for r in rules.split(","))
                else:
                    self.ignore_all.add(i)

    def suppressed(self, f: Finding) -> bool:
        if f.line in self.ignore_all:
            return True
        if f.rule in self.ignore_rules.get(f.line, ()):
            return True
        if f.line in self.sync_ok and f.rule.startswith(SYNC_FAMILY_PREFIX):
            return True
        return False


@dataclass
class PassContext:
    """Shared per-file state handed to every pass."""
    root: Path                       # path findings are reported relative to
    suppressions: Suppressions = None
    rules: Optional[set] = None      # None = all rules enabled

    def enabled(self, rule: str) -> bool:
        return self.rules is None or rule in self.rules


# --------------------------------------------------------------------------
# shared AST helpers used by several passes
# --------------------------------------------------------------------------
def dotted_name(node: ast.AST) -> str:
    """'jnp.asarray' for Attribute/Name chains, '' for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def qualname_map(tree: ast.AST) -> Dict[ast.AST, str]:
    """Map every FunctionDef/AsyncFunctionDef/Lambda to its qualname."""
    out: Dict[ast.AST, str] = {}

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                out[child] = q
                visit(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                if isinstance(child, ast.Lambda):
                    out[child] = f"{prefix}<lambda>"
                visit(child, prefix)

    visit(tree, "")
    return out


def enclosing_function_ranges(tree: ast.AST) -> List[tuple]:
    """[(start, end, qualname)] for every def, innermost resolvable last."""
    spans = []
    for node, q in qualname_map(tree).items():
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            spans.append((node.lineno, node.end_lineno or node.lineno, q))
    spans.sort(key=lambda s: (s[0], -(s[1])))
    return spans


def qualname_at(spans: List[tuple], line: int) -> str:
    best = ""
    for start, end, q in spans:
        if start <= line <= end:
            best = q          # spans are sorted outer-first; keep innermost
    return best


# --------------------------------------------------------------------------
# lint drivers
# --------------------------------------------------------------------------
def _passes():
    # Imported lazily so `import repro.analysis.core` never cycles.
    from repro.analysis import (donation, host_sync, pallas_contracts,
                                pool_lifetime, retrace, sharding_contracts)
    return (retrace, host_sync, pallas_contracts, pool_lifetime,
            sharding_contracts, donation)


def all_rules() -> Dict[str, str]:
    out: Dict[str, str] = {}
    for p in _passes():
        out.update(p.RULES)
    return dict(sorted(out.items()))


def lint_source(src: str, path: str = "<string>",
                root: Optional[Path] = None,
                rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one source string.  The API tests drive; the CLI wraps this."""
    ctx = PassContext(root=root or Path("."),
                      rules=set(rules) if rules is not None else None)
    ctx.suppressions = Suppressions(src)
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("HL000", path, e.lineno or 1, e.offset or 0,
                        f"syntax error: {e.msg}")]
    findings: List[Finding] = []
    for p in _passes():
        findings.extend(p.run(tree, src, path, ctx))
    findings = [f for f in findings if not ctx.suppressions.suppressed(f)]
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: Sequence[Path], root: Optional[Path] = None,
               rules: Optional[Iterable[str]] = None) -> List[Finding]:
    root = (root or Path.cwd()).resolve()
    findings: List[Finding] = []
    for f in iter_py_files([Path(p) for p in paths]):
        try:
            rel = f.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        src = f.read_text()
        findings.extend(lint_source(src, rel, root=root, rules=rules))
    # interprocedural passes can surface one defect from several entry
    # files — keep the first sighting only
    seen, out = set(), []
    for f in findings:
        k = (f.rule, f.path, f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------
BASELINE_VERSION = 1


def write_baseline(findings: Sequence[Finding], path: Path) -> None:
    doc = {
        "version": BASELINE_VERSION,
        "findings": [
            {"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
             "qualname": f.qualname, "message": f.message}
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
        ],
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")


def load_baseline(path: Path) -> Dict[str, dict]:
    doc = json.loads(path.read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version: {doc.get('version')}")
    return {e["fingerprint"]: e for e in doc.get("findings", [])}


def diff_baseline(findings: Sequence[Finding], baseline: Dict[str, dict]):
    """-> (new_findings, fixed_baseline_entries)."""
    current = {f.fingerprint for f in findings}
    new = [f for f in findings if f.fingerprint not in baseline]
    fixed = [e for fp, e in baseline.items() if fp not in current]
    return new, fixed
