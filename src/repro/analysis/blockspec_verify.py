"""hornshape verifier: BlockSpec/grid safety proofs with counterexamples.

Given a captured ``pallas_call`` geometry (``symbolic.Capture`` or a
directly-constructed :class:`Geometry`), prove per grid launch:

* **HS001 in-bounds** — every block-index an ``index_map`` can produce is
  inside ``[0, ceil(dim / block) - 1]`` for *every* grid step (including
  ragged tails), and every scalar-table lookup index is inside the table.
* **HS002 coverage hole / HS003 double-write** — the output grid, reduced
  over legitimate accumulator-carry dims (grid dims the out map is
  independent of *and* that are declared ``"arbitrary"``), covers each
  output block exactly once.  A revisit dim declared ``"parallel"`` is a
  double-write by construction.
* **HS004 consistency** — index-map arity vs array rank, block-shape rank,
  ``input_output_aliases`` dtype/shape agreement, positive scratch shapes.
* **HS005 null-page contract** — block-table gathers must select the
  module's ``NULL_PAGE`` for dead steps and clamp with ``min(_, W - 1)``
  where ``W`` is the table width (the pool's page-table width), checked
  symbolically, not syntactically (HL304's upgrade).
* **HS006 analysis incomplete** — the geometry defeats both the symbolic
  domains and bounded enumeration; reported, never silently passed.

Verdicts are decided symbolically where the interval/congruence domains
suffice (``method == "symbolic"``), else by exact enumeration of every
grid point (``method == "enumerated"``) — so a clean report is a proof
either way, and every failure carries a concrete counterexample grid
point.  ``brute_force`` recomputes all verdicts purely by enumeration;
the hypothesis property test checks the two always agree.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.core import Finding
from repro.analysis.symbolic import (AnalysisError, Capture, GridSpecV,
                                     ScratchV, ShapeDtypeV, Sym, SymBool,
                                     Table, concrete_all, free_vars,
                                     lookups_in, prove, sym, var)

_ENUM_LIMIT = 200_000

RULES = {
    "HS001": "index_map window out of bounds for some grid step",
    "HS002": "output grid leaves a block unwritten (coverage hole)",
    "HS003": "output block written more than once outside an "
             "accumulator-carry dim (double-write)",
    "HS004": "BlockSpec/alias/scratch inconsistency (rank, dtype, shape)",
    "HS005": "block-table gather violates the null-page clamp contract",
    "HS006": "geometry defeats symbolic + enumeration analysis",
}


class GeometryError(Exception):
    """The capture cannot be turned into a checkable geometry."""


@dataclass
class Operand:
    name: str
    shape: Tuple[int, ...]
    dtype: str
    block_shape: Tuple[int, ...]
    index_map: object                    # callable on (grid syms[, tables])
    memory_space: Optional[str] = None

    def nblocks(self) -> Tuple[int, ...]:
        return tuple(-(-s // b) for s, b in zip(self.shape, self.block_shape))


@dataclass
class Geometry:
    name: str
    grid: Tuple[int, ...]
    in_operands: List[Operand]
    out_operands: List[Operand]
    scalar_tables: List[Table] = field(default_factory=list)
    scratch: List[ScratchV] = field(default_factory=list)
    dimension_semantics: Optional[Tuple[str, ...]] = None
    input_output_aliases: Optional[Dict[int, int]] = None
    # (block-table name, NULL_PAGE): every gather into that table must be
    # where-guarded to NULL_PAGE and min-clamped to the table width - 1
    null_page: Optional[Tuple[str, int]] = None
    path: str = "<geometry>"
    lineno: int = 0

    def grid_env(self) -> Dict[str, Tuple[int, int]]:
        return {f"g{d}": (0, e - 1) for d, e in enumerate(self.grid)}

    def grid_vars(self) -> Tuple[Sym, ...]:
        return tuple(var(f"g{d}") for d in range(len(self.grid)))


@dataclass
class Report:
    geometry: Geometry
    findings: List[Finding] = field(default_factory=list)
    verdicts: Dict[tuple, object] = field(default_factory=dict)
    methods: Dict[tuple, str] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings

    def proved_symbolically(self) -> int:
        return sum(1 for m in self.methods.values() if m == "symbolic")

    def render(self) -> List[str]:
        out = [f"{self.geometry.name}: grid={self.geometry.grid} "
               f"in={len(self.geometry.in_operands)} "
               f"out={len(self.geometry.out_operands)}"]
        if self.ok:
            n_sym = self.proved_symbolically()
            n_enum = sum(1 for m in self.methods.values()
                         if m == "enumerated")
            out.append(f"  PROVED: {len(self.verdicts)} obligations "
                       f"({n_sym} symbolic, {n_enum} enumerated)")
        for f in self.findings:
            out.append("  " + f.render())
        return out


# --------------------------------------------------------------------------
# capture -> geometry
# --------------------------------------------------------------------------
def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def geometry_from_capture(cap: Capture, name: str,
                          path: str = "<capture>",
                          null_page: Optional[Tuple[str, int]] = None
                          ) -> Geometry:
    kw = cap.kwargs
    gs = kw.get("grid_spec")
    if isinstance(gs, GridSpecV):
        nsp = int(gs.num_scalar_prefetch or 0)
        grid, in_specs = gs.grid, _as_list(gs.in_specs)
        out_specs, scratch = _as_list(gs.out_specs), _as_list(
            gs.scratch_shapes)
    else:
        nsp = 0
        grid = tuple(kw.get("grid") or ())
        in_specs = _as_list(kw.get("in_specs"))
        out_specs = _as_list(kw.get("out_specs"))
        scratch = _as_list(kw.get("scratch_shapes"))
    if not grid or not all(isinstance(e, int) and e > 0 for e in grid):
        raise GeometryError(f"{name}: grid {grid!r} is not a tuple of "
                            f"positive ints")
    tables = list(cap.args[:nsp])
    for t in tables:
        if not isinstance(t, Table):
            raise GeometryError(
                f"{name}: scalar-prefetch operand {t!r} is not a Table")
    data_args = cap.args[nsp:]
    if len(in_specs) != len(data_args):
        raise GeometryError(
            f"{name}: {len(in_specs)} in_specs but {len(data_args)} "
            f"non-scalar call args")
    ins = []
    for i, (spec, arr) in enumerate(zip(in_specs, data_args)):
        ins.append(Operand(
            name=f"in{i}", shape=tuple(arr.shape),
            dtype=getattr(arr, "dtype", "int32"),
            block_shape=spec.block_shape, index_map=spec.index_map,
            memory_space=getattr(spec, "memory_space", None)))
    out_shapes = _as_list(kw.get("out_shape"))
    if len(out_specs) != len(out_shapes):
        raise GeometryError(
            f"{name}: {len(out_specs)} out_specs but {len(out_shapes)} "
            f"out_shapes")
    outs = []
    for i, (spec, sds) in enumerate(zip(out_specs, out_shapes)):
        if not isinstance(sds, ShapeDtypeV):
            raise GeometryError(f"{name}: out_shape {sds!r} is not a "
                                f"ShapeDtypeStruct")
        outs.append(Operand(
            name=f"out{i}", shape=sds.shape, dtype=sds.dtype,
            block_shape=spec.block_shape, index_map=spec.index_map,
            memory_space=getattr(spec, "memory_space", None)))
    cp = kw.get("compiler_params")
    sem = None
    if isinstance(cp, dict) and cp.get("dimension_semantics") is not None:
        sem = tuple(cp["dimension_semantics"])
    aliases = kw.get("input_output_aliases")
    aliases = dict(aliases) if aliases else None
    return Geometry(name=name, grid=tuple(grid), in_operands=ins,
                    out_operands=outs, scalar_tables=tables,
                    scratch=[s for s in scratch if isinstance(s, ScratchV)],
                    dimension_semantics=sem, input_output_aliases=aliases,
                    null_page=null_page, path=path, lineno=cap.lineno)


# --------------------------------------------------------------------------
# shared evaluation helpers
# --------------------------------------------------------------------------
def _call_index_map(geom: Geometry, op: Operand, args):
    im = op.index_map
    if im is None:
        # pallas default: identity over leading grid dims
        return tuple(args[:len(op.shape)])
    if geom.scalar_tables:
        return im(*args, *geom.scalar_tables)
    return im(*args)


def _idx_tuple(geom: Geometry, op: Operand):
    """Symbolic index tuple of ``op``'s map, or an HS004/HS006 message."""
    try:
        res = _call_index_map(geom, op, geom.grid_vars())
    except AnalysisError as e:
        raise GeometryError(f"{op.name} index_map: {e}")
    if isinstance(res, (Sym, int)):
        res = (res,)
    if not isinstance(res, tuple):
        raise GeometryError(
            f"{op.name} index_map returned {type(res).__name__}, "
            f"expected a tuple of block indices")
    return tuple(sym(x) for x in res)


def _iter_grid(grid: Sequence[int], dims: Optional[Sequence[int]] = None):
    dims = list(range(len(grid))) if dims is None else list(dims)
    point = [0] * len(grid)

    def rec(i):
        if i == len(dims):
            yield {f"g{d}": point[d] for d in range(len(grid))}
            return
        d = dims[i]
        for v in range(grid[d]):
            point[d] = v
            yield from rec(i + 1)

    yield from rec(0)


def _fmt_point(point: Dict[str, int], dims: Optional[Sequence[int]] = None):
    keys = sorted(point, key=lambda k: int(k[1:]))
    if dims is not None:
        keep = {f"g{d}" for d in dims}
        keys = [k for k in keys if k in keep]
    return "(" + ", ".join(f"{k}={point[k]}" for k in keys) + ")"


def _enum_size(grid: Sequence[int], dims=None) -> int:
    dims = range(len(grid)) if dims is None else dims
    return math.prod(grid[d] for d in dims) if dims else 1


# --------------------------------------------------------------------------
# the verifier
# --------------------------------------------------------------------------
class _Verifier:
    def __init__(self, geom: Geometry):
        self.g = geom
        self.rep = Report(geom)
        self.env = geom.grid_env()

    def finding(self, rule: str, message: str):
        self.rep.findings.append(Finding(
            rule, self.g.path, self.g.lineno, 0,
            f"{self.g.name}: {message}", self.g.name))

    # -- obligations ---------------------------------------------------
    def _discharge(self, key, ob: SymBool, describe, value_expr=None):
        """Prove ``ob`` for all grid points or find a counterexample."""
        v = prove(ob, self.env)
        if v is True:
            self.rep.verdicts[key] = True
            self.rep.methods[key] = "symbolic"
            return
        if _enum_size(self.g.grid) > _ENUM_LIMIT:
            self.rep.verdicts[key] = None
            self.rep.methods[key] = "incomplete"
            self.finding("HS006", f"{describe}: inconclusive symbolically "
                                  f"and grid too large to enumerate")
            return
        for point in _iter_grid(self.g.grid):
            try:
                vals = concrete_all(ob, point)
            except AnalysisError as e:
                self.rep.verdicts[key] = None
                self.rep.methods[key] = "incomplete"
                self.finding("HS006", f"{describe}: {e}")
                return
            if False in vals:
                self.rep.verdicts[key] = False
                self.rep.methods[key] = "enumerated"
                detail = ""
                if value_expr is not None:
                    got = sorted(concrete_all(value_expr, point))
                    detail = f" (index value {got[0] if len(got) == 1 else got})"
                self.finding("HS001", f"{describe}: counterexample grid "
                                      f"point {_fmt_point(point)}{detail}")
                return
        self.rep.verdicts[key] = True
        self.rep.methods[key] = "enumerated"

    def check_operand(self, op: Operand):
        nd = len(op.shape)
        if op.block_shape is None or len(op.block_shape) != nd:
            self.finding("HS004", f"{op.name}: block_shape "
                                  f"{op.block_shape} does not match array "
                                  f"rank {nd} (shape {op.shape})")
            return
        try:
            idx = _idx_tuple(self.g, op)
        except GeometryError as e:
            self.finding("HS006", str(e))
            return None
        if len(idx) != nd:
            self.finding("HS004", f"{op.name}: index_map returns "
                                  f"{len(idx)} indices but the array has "
                                  f"rank {nd}")
            return None
        for d, e in enumerate(idx):
            hi = op.nblocks()[d] - 1
            self._discharge(
                ("inbounds", op.name, d),
                (e >= 0) & (e <= hi),
                f"{op.name} dim {d}: block index {e!r} must be in "
                f"[0, {hi}]", value_expr=e)
        # scalar-table lookup indices must themselves be in bounds
        for li, lk in enumerate(self._lookups(idx)):
            table = lk.args[0]
            for k, ie in enumerate(lk.args[1]):
                bound = table.shape[k] - 1
                self._discharge(
                    ("lookup", op.name, li, k),
                    (ie >= 0) & (ie <= bound),
                    f"{op.name}: lookup index {k} into {table.name} "
                    f"{ie!r} must be in [0, {bound}]", value_expr=ie)
        return idx

    @staticmethod
    def _lookups(idx) -> List[Sym]:
        seen, out = set(), []
        for e in idx:
            for lk in lookups_in(e):
                if id(lk) not in seen:
                    seen.add(id(lk))
                    out.append(lk)
        return out

    # -- coverage ------------------------------------------------------
    def check_coverage(self, op: Operand, idx):
        key = ("coverage", op.name)
        fv = set()
        for e in idx:
            fv |= free_vars(e)
        revisit = [d for d in range(len(self.g.grid))
                   if f"g{d}" not in fv and self.g.grid[d] > 1]
        sem = self.g.dimension_semantics
        for d in revisit:
            if sem is not None and d < len(sem) and sem[d] == "parallel":
                self.rep.verdicts[key] = "double"
                self.rep.methods[key] = "symbolic"
                self.finding(
                    "HS003", f"{op.name}: grid dim {d} (extent "
                    f"{self.g.grid[d]}) revisits every output block but is "
                    f"declared 'parallel' — double-write across cores")
                return
        reduced = [d for d in range(len(self.g.grid)) if d not in revisit]
        if self._bijection_fast_path(op, idx, reduced):
            self.rep.verdicts[key] = "exact"
            self.rep.methods[key] = "symbolic"
            return
        self._coverage_enumerate(op, idx, reduced, key)

    def _bijection_fast_path(self, op: Operand, idx, reduced) -> bool:
        """Each out dim is either the constant 0 (single block) or a
        distinct reduced grid var with coefficient 1 and matching extent;
        all reduced vars consumed -> a bijection, proved symbolically."""
        from repro.analysis.symbolic import _linearize
        used = set()
        nb = op.nblocks()
        for d, e in enumerate(idx):
            try:
                c, vs, ops = _linearize(e)
            except AnalysisError:
                return False
            if ops:
                return False
            if not vs:
                if c == 0 and nb[d] == 1:
                    continue
                return False
            if len(vs) != 1 or c != 0:
                return False
            (name, coeff), = vs.items()
            if coeff != 1 or name in used or not name.startswith("g"):
                return False
            gd = int(name[1:])
            if gd not in reduced or self.g.grid[gd] != nb[d]:
                return False
            used.add(name)
        return used == {f"g{d}" for d in reduced if self.g.grid[d] > 1} \
            or used == {f"g{d}" for d in reduced}

    def _coverage_enumerate(self, op: Operand, idx, reduced, key):
        nb = op.nblocks()
        if _enum_size(self.g.grid, reduced) > _ENUM_LIMIT \
                or math.prod(nb) > _ENUM_LIMIT:
            self.rep.verdicts[key] = None
            self.rep.methods[key] = "incomplete"
            self.finding("HS006", f"{op.name}: coverage not provable "
                                  f"symbolically and grid too large to "
                                  f"enumerate")
            return
        counts: Dict[tuple, dict] = {}
        for point in _iter_grid(self.g.grid, reduced):
            vals = []
            for e in idx:
                try:
                    vs = concrete_all(e, point)
                except AnalysisError as err:
                    self.rep.verdicts[key] = None
                    self.rep.methods[key] = "incomplete"
                    self.finding("HS006", f"{op.name}: coverage: {err}")
                    return
                if len(vs) != 1:
                    self.rep.verdicts[key] = None
                    self.rep.methods[key] = "incomplete"
                    self.finding(
                        "HS006", f"{op.name}: output index depends on "
                        f"scalar-table contents at {_fmt_point(point, reduced)}"
                        f" — cannot prove exact coverage")
                    return
                vals.append(next(iter(vs)))
            block = tuple(vals)
            entry = counts.setdefault(block, {"n": 0, "first": None})
            if entry["first"] is None:
                entry["first"] = _fmt_point(point, reduced)
            elif entry["n"] == 1:
                self.rep.verdicts[key] = "double"
                self.rep.methods[key] = "enumerated"
                self.finding(
                    "HS003", f"{op.name}: output block {block} written by "
                    f"both grid points {entry['first']} and "
                    f"{_fmt_point(point, reduced)}")
                return
            entry["n"] += 1
        for block_idx in _iter_grid(nb):
            block = tuple(block_idx[f"g{d}"] for d in range(len(nb)))
            if block not in counts:
                self.rep.verdicts[key] = "hole"
                self.rep.methods[key] = "enumerated"
                self.finding(
                    "HS002", f"{op.name}: output block {block} is never "
                    f"written (coverage hole over blocks {nb})")
                return
        self.rep.verdicts[key] = "exact"
        self.rep.methods[key] = "enumerated"

    # -- aliases / scratch / null page ---------------------------------
    def check_aliases(self):
        al = self.g.input_output_aliases
        if not al:
            return
        for i, o in al.items():
            if not (isinstance(i, int) and 0 <= i < len(self.g.in_operands)
                    and isinstance(o, int)
                    and 0 <= o < len(self.g.out_operands)):
                self.finding("HS004", f"input_output_aliases {{{i}: {o}}} "
                                      f"out of operand range")
                continue
            a, b = self.g.in_operands[i], self.g.out_operands[o]
            if a.shape != b.shape or a.dtype != b.dtype:
                self.finding(
                    "HS004", f"alias in{i}->out{o}: {a.shape}/{a.dtype} vs "
                    f"{b.shape}/{b.dtype} — donated buffers must match "
                    f"exactly")
            elif a.block_shape != b.block_shape:
                self.finding(
                    "HS004", f"alias in{i}->out{o}: block shapes "
                    f"{a.block_shape} vs {b.block_shape} differ")

    def check_scratch(self):
        for i, s in enumerate(self.g.scratch):
            if not all(isinstance(d, int) and d > 0 for d in s.shape):
                self.finding("HS004", f"scratch {i}: shape {s.shape} must "
                                      f"be positive ints")

    def check_null_page(self):
        if self.g.null_page is None:
            return
        table_name, null_page = self.g.null_page
        tables = {t.name: t for t in self.g.scalar_tables}
        if table_name not in tables:
            self.finding("HS005", f"null-page contract names table "
                                  f"{table_name!r} but the geometry has "
                                  f"{sorted(tables)}")
            return
        width = tables[table_name].shape[-1]
        key = ("null_page",)
        checked = 0
        for op in self.g.in_operands:
            try:
                idx = _idx_tuple(self.g, op)
            except GeometryError:
                continue
            for lk in self._lookups(idx):
                if lk.args[0].name != table_name:
                    continue
                checked += 1
                if not self._null_guarded(idx, lk, null_page):
                    self.finding(
                        "HS005", f"{op.name}: gather {lk!r} has no "
                        f"where(live, ..., {null_page}) guard selecting "
                        f"NULL_PAGE={null_page} for dead grid steps")
                    self.rep.verdicts[key] = False
                    self.rep.methods[key] = "symbolic"
                    return
                clamp = self._min_clamp_const(lk)
                if clamp is None:
                    self.finding(
                        "HS005", f"{op.name}: gather {lk!r} index has no "
                        f"min(_, const) clamp into the table")
                    self.rep.verdicts[key] = False
                    self.rep.methods[key] = "symbolic"
                    return
                if prove(sym(clamp) == width - 1, {}) is not True:
                    self.finding(
                        "HS005", f"{op.name}: clamp bound {clamp} != table "
                        f"width - 1 = {width - 1} — the clamp must equal "
                        f"the block-table width")
                    self.rep.verdicts[key] = False
                    self.rep.methods[key] = "symbolic"
                    return
        if checked:
            self.rep.verdicts[key] = True
            self.rep.methods[key] = "symbolic"

    @staticmethod
    def _null_guarded(idx, lk: Sym, null_page: int) -> bool:
        def holds(e) -> bool:
            stack = [e]
            while stack:
                n = stack.pop()
                if n is lk:
                    return True
                if isinstance(n, Sym):
                    if n.op == "lookup":
                        stack.extend(n.args[1])
                    else:
                        stack.extend(n.args)
                elif isinstance(n, SymBool):
                    stack.extend(a for a in n.args
                                 if isinstance(a, (Sym, SymBool)))
            return False

        for e in idx:
            stack = [e]
            while stack:
                n = stack.pop()
                if isinstance(n, Sym):
                    if n.op == "where":
                        _, a, b = n.args
                        if holds(a) and b.op == "const" \
                                and b.args[0] == null_page:
                            return True
                    if n.op == "lookup":
                        stack.extend(n.args[1])
                    else:
                        stack.extend(n.args)
                elif isinstance(n, SymBool):
                    stack.extend(x for x in n.args
                                 if isinstance(x, (Sym, SymBool)))
        return False

    @staticmethod
    def _min_clamp_const(lk: Sym) -> Optional[int]:
        for ie in lk.args[1]:
            stack = [ie]
            while stack:
                n = stack.pop()
                if isinstance(n, Sym):
                    if n.op == "min":
                        for a in n.args:
                            if a.op == "const":
                                return a.args[0]
                    stack.extend(a for a in n.args if isinstance(a, Sym))
        return None


def verify(geom: Geometry) -> Report:
    v = _Verifier(geom)
    for op in geom.in_operands:
        v.check_operand(op)
    for op in geom.out_operands:
        idx = v.check_operand(op)
        if idx is not None:
            v.check_coverage(op, idx)
    v.check_aliases()
    v.check_scratch()
    v.check_null_page()
    return v.rep


# --------------------------------------------------------------------------
# ground truth: exhaustive enumeration (the property test's oracle)
# --------------------------------------------------------------------------
def brute_force(geom: Geometry) -> Dict[tuple, object]:
    """Recompute every in-bounds/lookup/coverage verdict by enumerating
    all grid points.  Raises GeometryError if the geometry is too large
    or genuinely not enumerable."""
    if _enum_size(geom.grid) > _ENUM_LIMIT:
        raise GeometryError("grid too large to brute-force")
    verdicts: Dict[tuple, object] = {}
    idx_of = {}
    for op in geom.in_operands + geom.out_operands:
        try:
            idx = _idx_tuple(geom, op)
        except GeometryError:
            continue
        if len(idx) != len(op.shape):
            continue
        idx_of[op.name] = (op, idx)
        nb = op.nblocks()
        for d, e in enumerate(idx):
            ok = True
            for point in _iter_grid(geom.grid):
                vals = concrete_all(e, point)
                if any(not 0 <= x <= nb[d] - 1 for x in vals):
                    ok = False
                    break
            verdicts[("inbounds", op.name, d)] = ok
        for li, lk in enumerate(_Verifier._lookups(idx)):
            table = lk.args[0]
            for k, ie in enumerate(lk.args[1]):
                ok = True
                for point in _iter_grid(geom.grid):
                    vals = concrete_all(ie, point)
                    if any(not 0 <= x <= table.shape[k] - 1 for x in vals):
                        ok = False
                        break
                verdicts[("lookup", op.name, li, k)] = ok
    for op in geom.out_operands:
        if op.name not in idx_of:
            continue
        _, idx = idx_of[op.name]
        fv = set()
        for e in idx:
            fv |= free_vars(e)
        revisit = [d for d in range(len(geom.grid))
                   if f"g{d}" not in fv and geom.grid[d] > 1]
        sem = geom.dimension_semantics
        key = ("coverage", op.name)
        if any(sem is not None and d < len(sem) and sem[d] == "parallel"
               for d in revisit):
            verdicts[key] = "double"
            continue
        reduced = [d for d in range(len(geom.grid)) if d not in revisit]
        nb = op.nblocks()
        counts: Dict[tuple, int] = {}
        bad = None
        for point in _iter_grid(geom.grid, reduced):
            vals = []
            for e in idx:
                vs = concrete_all(e, point)
                if len(vs) != 1:
                    bad = "nondeterministic"
                    break
                vals.append(next(iter(vs)))
            if bad:
                break
            counts[tuple(vals)] = counts.get(tuple(vals), 0) + 1
        if bad:
            verdicts[key] = None
            continue
        if any(n > 1 for n in counts.values()):
            verdicts[key] = "double"
        elif any(tuple(p[f"g{d}"] for d in range(len(nb))) not in counts
                 for p in _iter_grid(nb)):
            verdicts[key] = "hole"
        else:
            verdicts[key] = "exact"
    return verdicts
