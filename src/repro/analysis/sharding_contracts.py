"""HL5xx — shard_map / PartitionSpec / collective contracts.

The mesh scale-out's bug class: a wrong ``in_specs`` arity or a typo'd axis
name doesn't crash under ``check_vma=False`` — it silently re-replicates or
mis-partitions and corrupts results.  These rules pin the statically
checkable parts:

* HL501 ``shard-map-arity``: a literal ``in_specs`` tuple/list passed to
  ``shard_map`` must match the wrapped function's positional signature
  (resolved in-file; ``Name`` specs and non-literal spec containers are
  skipped — dynamic construction is the ``ShardingCtx`` path, which jax
  checks at trace time).
* HL502 ``partition-axis-name``: every *string-literal* axis name inside a
  ``PartitionSpec(...)``/``P(...)`` must exist in the mesh vocabulary —
  the axis tuples of every ``Mesh``/``jax.make_mesh`` construction in the
  linted file plus ``launch/mesh.py`` under the lint root (fallback:
  ``{"pod", "data", "model"}``, the production mesh).
* HL503 ``spec-rank``: where an argument to a shard_mapped function has a
  statically known rank (a local ``jnp.zeros((...))``-style literal), a
  literal ``P(...)`` spec for it must not have more entries than the
  array has dims.
* HL504 ``collective-axis-binding``: a collective (``psum``/``pmean``/
  ``ppermute``/``all_gather``/``axis_index``/...) with a *literal* axis
  name must appear inside a function wrapped by a ``shard_map`` in the
  same file, and the axis must be in the mesh vocabulary.  Collectives
  taking axis names from parameters/variables are skipped (they are bound
  by their callers — jax raises at trace time if not).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis.core import (Finding, PassContext, dotted_name,
                                 enclosing_function_ranges, qualname_at)

RULES = {
    "HL501": "shard_map in_specs arity must match the wrapped fn signature",
    "HL502": "PartitionSpec axis name must exist in the mesh",
    "HL503": "PartitionSpec rank must not exceed the array rank",
    "HL504": "collective axis name must be bound by an enclosing shard_map "
             "and exist in the mesh",
}

_DEFAULT_AXES = {"pod", "data", "model"}
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "ppermute", "all_gather",
                "all_to_all", "psum_scatter", "axis_index"}
_ARRAY_CTORS = {"zeros", "ones", "full", "empty", "arange", "array"}

_mesh_axes_cache: Dict[str, Set[str]] = {}


def _literal_axis_strings(node: ast.AST) -> List[str]:
    """String literals used as axis entries in a P(...)/Mesh(...) arg."""
    out = []
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.append(node.value)
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
    return out


def _axes_from_tree(tree: ast.AST) -> Set[str]:
    """Axis names from every Mesh(...)/make_mesh(...) call in a module —
    including literal tuples reached through one Name/IfExp indirection."""
    consts: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            consts[node.targets[0].id] = node.value
    axes: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tail = dotted_name(node.func).split(".")[-1]
        if tail not in ("Mesh", "make_mesh"):
            continue
        for arg in list(node.args) + [k.value for k in node.keywords]:
            if isinstance(arg, ast.Name):
                arg = consts.get(arg.id, arg)
            for sub in ast.walk(arg):
                if isinstance(sub, (ast.Tuple, ast.List)):
                    names = _literal_axis_strings(sub)
                    if names and len(names) == len(sub.elts):
                        axes.update(names)
    return axes


def _mesh_vocabulary(tree: ast.AST, ctx: PassContext) -> Set[str]:
    axes = set(_DEFAULT_AXES) | _axes_from_tree(tree)
    mesh_py = Path(ctx.root) / "src" / "repro" / "launch" / "mesh.py"
    key = str(mesh_py)
    if key not in _mesh_axes_cache:
        found: Set[str] = set()
        try:
            found = _axes_from_tree(ast.parse(mesh_py.read_text()))
        except (OSError, SyntaxError):
            pass
        _mesh_axes_cache[key] = found
    return axes | _mesh_axes_cache[key]


def _is_pspec_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name == "P" or name.split(".")[-1] == "PartitionSpec"


def _pspec_entries(node: ast.Call) -> Optional[int]:
    if node.keywords:
        return None
    return len(node.args)


def _required_total(fnargs: ast.arguments):
    req = len(fnargs.posonlyargs) + len(fnargs.args) - len(fnargs.defaults)
    total = len(fnargs.posonlyargs) + len(fnargs.args)
    return req, total, fnargs.vararg is not None


def _static_ranks(fn: ast.AST) -> Dict[str, int]:
    """name -> ndim for locals bound to literal-shape array constructors."""
    ranks: Dict[str, int] = {}
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        tail = dotted_name(call.func).split(".")[-1]
        if tail not in _ARRAY_CTORS or not call.args:
            continue
        shape = call.args[0]
        if tail == "arange":
            ranks[node.targets[0].id] = 1
        elif isinstance(shape, (ast.Tuple, ast.List)):
            ranks[node.targets[0].id] = len(shape.elts)
        elif isinstance(shape, ast.Constant) and isinstance(shape.value, int):
            ranks[node.targets[0].id] = 1
    return ranks


def run(tree: ast.AST, src: str, path: str, ctx: PassContext) -> List[Finding]:
    if "shard_map" not in src and "PartitionSpec" not in src \
            and not any(c in src for c in _COLLECTIVES):
        return []
    findings: List[Finding] = []
    spans = enclosing_function_ranges(tree)
    vocab = _mesh_vocabulary(tree, ctx)
    all_defs = [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]

    def resolve_def(name: str, at_line: int) -> Optional[ast.FunctionDef]:
        """The nearest def of ``name`` lexically preceding ``at_line`` —
        the one in scope when nested fns shadow a module-level name."""
        best = None
        for d in all_defs:
            if d.name == name and d.lineno <= at_line \
                    and (best is None or d.lineno > best.lineno):
                best = d
        return best

    # ---- collect shard_map calls + the regions their wrapped fns span ----
    wrapped_spans: List[tuple] = []
    sm_calls: List[ast.Call] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and dotted_name(node.func).split(".")[-1] == "shard_map" \
                and node.args:
            sm_calls.append(node)
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                wrapped_spans.append((target.lineno,
                                      target.end_lineno or target.lineno))
            elif isinstance(target, ast.Name):
                d = resolve_def(target.id, node.lineno)
                if d is not None:
                    wrapped_spans.append((d.lineno, d.end_lineno or d.lineno))

    def kw(call: ast.Call, name: str):
        for k in call.keywords:
            if k.arg == name:
                return k.value
        return None

    # ---- HL501 arity + HL503 rank ----
    for call in sm_calls:
        target = call.args[0]
        fn = None
        if isinstance(target, ast.Lambda):
            fn = target
        elif isinstance(target, ast.Name):
            fn = resolve_def(target.id, call.lineno)
        in_specs = kw(call, "in_specs")
        n_specs = None
        if isinstance(in_specs, (ast.Tuple, ast.List)) \
                and not any(isinstance(e, ast.Starred)
                            for e in in_specs.elts):
            n_specs = len(in_specs.elts)
        if ctx.enabled("HL501") and fn is not None and n_specs is not None:
            req, total, has_var = _required_total(fn.args)
            if n_specs < req or (n_specs > total and not has_var):
                fname = getattr(target, "id", "<lambda>")
                findings.append(Finding(
                    "HL501", path, call.lineno, call.col_offset,
                    f"shard_map in_specs has {n_specs} specs but "
                    f"{fname}() takes "
                    f"{req if req == total else f'{req}..{total}'} "
                    f"positional args", qualname_at(spans, call.lineno)))
        # HL503: result called in place or via a local name, with literal
        # P(...) specs and statically-ranked array args
        if ctx.enabled("HL503") and n_specs is not None:
            self_fn = None
            for start, end, _q in spans:
                if start <= call.lineno <= end:
                    self_fn = (start, end)
            owner = None
            for d in all_defs:
                if (d.lineno, d.end_lineno or d.lineno) == self_fn:
                    owner = d
            ranks = _static_ranks(owner) if owner is not None else {}
            for use in _shard_mapped_calls(tree, call, self_fn):
                for i, arg in enumerate(use.args[:n_specs]):
                    spec = in_specs.elts[i]
                    if not (isinstance(spec, ast.Call)
                            and _is_pspec_call(spec)):
                        continue
                    n_entries = _pspec_entries(spec)
                    nd = ranks.get(arg.id) \
                        if isinstance(arg, ast.Name) else None
                    if n_entries is not None and nd is not None \
                            and n_entries > nd:
                        findings.append(Finding(
                            "HL503", path, use.lineno, use.col_offset,
                            f"in_specs[{i}] has {n_entries} partition "
                            f"entries but argument {arg.id!r} has rank "
                            f"{nd}", qualname_at(spans, use.lineno)))

    # ---- HL502 axis names ----
    if ctx.enabled("HL502"):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_pspec_call(node):
                for arg in node.args:
                    for name in _literal_axis_strings(arg):
                        if name not in vocab:
                            findings.append(Finding(
                                "HL502", path, node.lineno, node.col_offset,
                                f"PartitionSpec axis {name!r} is not a "
                                f"mesh axis (known: {sorted(vocab)})",
                                qualname_at(spans, node.lineno)))

    # ---- HL504 collective binding ----
    if ctx.enabled("HL504"):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            tail = dotted_name(node.func).split(".")[-1]
            if tail not in _COLLECTIVES:
                continue
            axis_nodes = list(node.args) + [k.value for k in node.keywords
                                            if k.arg in ("axis_name",
                                                         "axis_index_groups")
                                            and k.arg != "axis_index_groups"]
            names: List[str] = []
            for a in axis_nodes:
                names.extend(_literal_axis_strings(a))
            if not names:
                continue            # axis from a variable: caller-bound
            inside = any(s <= node.lineno <= e for s, e in wrapped_spans)
            qual = qualname_at(spans, node.lineno)
            if not inside:
                findings.append(Finding(
                    "HL504", path, node.lineno, node.col_offset,
                    f"collective {tail}(..., {names[0]!r}) is not inside "
                    f"any function wrapped by a shard_map in this module — "
                    f"the axis name is unbound", qual))
            for name in names:
                if name not in vocab:
                    findings.append(Finding(
                        "HL504", path, node.lineno, node.col_offset,
                        f"collective {tail} names axis {name!r} which is "
                        f"not a mesh axis (known: {sorted(vocab)})", qual))
    return findings


def _shard_mapped_calls(tree: ast.AST, sm_call: ast.Call,
                        owner_span) -> List[ast.Call]:
    """Call sites of ``sm_call``'s result: direct ``shard_map(...)(args)``
    or ``fn = shard_map(...)`` followed by ``fn(args)``.  Bound-name uses
    are restricted to the function that made the binding (``owner_span``;
    None means module scope) so same-named bindings in sibling functions
    don't cross-contaminate."""
    out: List[ast.Call] = []
    bound: Optional[str] = None
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and node.func is sm_call:
            out.append(node)
        if isinstance(node, ast.Assign) and node.value is sm_call \
                and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            bound = node.targets[0].id
    if bound is not None:
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == bound \
                    and (owner_span is None
                         or owner_span[0] <= node.lineno <= owner_span[1]):
                out.append(node)
    return out
