"""HL2xx — host-device sync leaks in hot paths.

A device value (result of the engine's jitted step calls or any
``jnp.``/``jax.`` array op) pulled to the host blocks the tick loop on a
transfer.  The engine has exactly two *deliberate* tick-forcing syncs
(commit path) plus the draft proposer's pull — those carry
``# hornlint: sync-ok``; everything else is a leak.

Analysis: per-function forward taint.  Sources taint names bound from

* calls through known device-step attributes (``self._step``,
  ``self._page_copy``) and curried steps (``self._step_for(k)(...)``),
* ``jnp.*`` / ``jax.*`` calls (minus host-transfer and metadata helpers),

propagated through assignments, tuple unpacking, arithmetic, subscripts
and unresolved calls that receive a tainted argument.  Taint dies on
rebinding from an untainted expression and on shape/dtype/len access
(static under trace).  Sinks:

* HL201 ``sync-host-pull``: ``np.asarray``/``np.array``/``jax.device_get``
  /``float``/``int``/``bool`` over a tainted value, ``.item()``,
  ``.tolist()``, ``.block_until_ready()``, or storing a tainted value
  into a subscript of an untainted (host) array.
* HL202 ``sync-in-loop``: the same sink lexically inside a for/while —
  a per-iteration transfer, the expensive variant.

Scope: only functions in the built-in hot-scope list below or marked
``# hornlint: hot-path`` on the ``def`` line are analyzed; setup and
reporting code is free to pull results.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.core import Finding, PassContext, dotted_name

RULES = {
    "HL201": "host pull of a device value in a hot path "
             "(annotate deliberate syncs with '# hornlint: sync-ok')",
    "HL202": "host pull of a device value inside a loop in a hot path",
}

# (path suffix, qualname prefixes) — functions on the engine tick path.
HOT_SCOPES = (
    ("serving/engine.py",
     ("Engine.step", "Engine._commit_spec", "Engine._flush_copies",
      "Engine._plan_tick", "Engine._try_plan", "Engine._prepare_entry_write",
      "Engine._sync_block_tables", "Engine._release", "Engine._sample_peak")),
    ("serving/speculative.py",
     ("DraftRunner.propose", "DraftRunner.commit", "DraftRunner.drop")),
    ("serving/block_table.py", ("BlockTableMirror.sync",)),
)

DEVICE_CALL_ATTRS = {"_step", "_page_copy", "_draft_step"}
CURRIED_STEP_ATTRS = {"_step_for"}
# jnp/jax helpers whose results are *not* device arrays (or are the sink).
_JAX_NON_DEVICE = {"jnp.dtype", "jnp.shape", "jnp.ndim", "jnp.result_type",
                   "jax.device_get", "jax.eval_shape", "jax.ShapeDtypeStruct",
                   "jax.jit", "jax.named_scope", "jax.tree_util",
                   "jax.random.PRNGKey"}
_SINK_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get", "float", "int", "bool"}
_SINK_METHODS = {"item", "tolist", "block_until_ready", "__array__"}
_TAINT_KILL_ATTRS = {"shape", "ndim", "dtype", "size"}


def _is_hot(path: str, qualname: str) -> bool:
    for suffix, prefixes in HOT_SCOPES:
        if path.endswith(suffix):
            return any(qualname == p or qualname.startswith(p + ".")
                       for p in prefixes)
    return False


class _Taint(ast.NodeVisitor):
    """Single forward pass over one function body, statement order."""

    def __init__(self, fn: ast.AST, path: str, qualname: str):
        self.fn = fn
        self.path = path
        self.qualname = qualname
        self.tainted: Set[str] = set()
        self.findings: List[Finding] = []
        self.loop_depth = 0

    # ---------------- expression taint ----------------
    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _TAINT_KILL_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            return self._call_taints(node)
        if isinstance(node, ast.Compare):
            return False        # bool result; comparison itself may sync but
        return False            # flagging `==` would drown real findings

    def _call_taints(self, call: ast.Call) -> bool:
        name = dotted_name(call.func)
        # a sink's result lives on the host: the pull is flagged where it
        # happens, downstream use of the result is free
        if name in _SINK_CALLS:
            return False
        # device-step calls: self._step(...), self._page_copy(...)
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in DEVICE_CALL_ATTRS:
            return True
        # curried: self._step_for(k)(...)
        if isinstance(call.func, ast.Call) \
                and isinstance(call.func.func, ast.Attribute) \
                and call.func.func.attr in CURRIED_STEP_ATTRS:
            return True
        if name.startswith(("jnp.", "jax.")):
            return name not in _JAX_NON_DEVICE
        if name in ("len", "isinstance", "type", "range", "enumerate",
                    "zip", "min", "max", "sorted", "str"):
            return False
        # method call on a device value (x.sum(), x.astype(...)) stays
        # on device (the *blocking* methods are sinks, handled above)
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr not in _SINK_METHODS \
                and self.is_tainted(call.func.value):
            return True
        # unresolved call: conservatively tainted if any argument is
        args = list(call.args) + [k.value for k in call.keywords]
        return any(self.is_tainted(a) for a in args)

    # ---------------- sinks ----------------
    def _emit(self, node: ast.AST, what: str) -> None:
        rule = "HL202" if self.loop_depth else "HL201"
        msg = (f"{what} forces a device->host sync"
               + (" every loop iteration" if self.loop_depth else ""))
        self.findings.append(Finding(rule, self.path, node.lineno,
                                     node.col_offset, msg, self.qualname))

    def _check_sink(self, call: ast.Call) -> bool:
        """True if this call is a sync sink over tainted input."""
        name = dotted_name(call.func)
        if name in _SINK_CALLS and call.args \
                and self.is_tainted(call.args[0]):
            self._emit(call, f"{name}() on a device value")
            return True
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _SINK_METHODS \
                and self.is_tainted(call.func.value):
            self._emit(call, f".{call.func.attr}() on a device value")
            return True
        return False

    def visit_Call(self, node: ast.Call) -> None:
        self._check_sink(node)
        self.generic_visit(node)

    # ---------------- statements / binding ----------------
    def _bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if tainted
             else self.tainted.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)
        elif isinstance(target, ast.Subscript):
            # storing a tainted value into a subscript of an untainted
            # object writes device data into a host array: a sync sink
            if tainted and not self.is_tainted(target.value):
                self._emit(target, "store of a device value into a host "
                                   "array slice")

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        t = self.is_tainted(node.value)
        for tgt in node.targets:
            if isinstance(tgt, (ast.Tuple, ast.List)) \
                    and isinstance(node.value, (ast.Tuple, ast.List)) \
                    and len(tgt.elts) == len(node.value.elts):
                for e, v in zip(tgt.elts, node.value.elts):
                    self._bind(e, self.is_tainted(v))
            else:
                self._bind(tgt, t)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.visit(node.value)
        if self.is_tainted(node.value):
            self._bind(node.target, True)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self.visit(node.value)
            self._bind(node.target, self.is_tainted(node.value))

    def visit_For(self, node: ast.For) -> None:
        self.visit(node.iter)
        self._bind(node.target, self.is_tainted(node.iter))
        self.loop_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_While(self, node: ast.While) -> None:
        self.visit(node.test)
        self.loop_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    # nested defs/lambdas get their own scope decision — skip here
    def visit_FunctionDef(self, node): pass
    def visit_AsyncFunctionDef(self, node): pass
    def visit_Lambda(self, node): pass


def run(tree: ast.AST, src: str, path: str, ctx: PassContext) -> List[Finding]:
    if not (ctx.enabled("HL201") or ctx.enabled("HL202")):
        return []
    # benchmarks and launch drivers pull results on purpose (reporting,
    # readiness probes) — hot-path sync rules only apply on the serving
    # tick path, even if a def there carries a hot-path marker
    norm = path.replace("\\", "/")
    if "benchmarks/" in norm or "repro/launch/" in norm:
        return []
    from repro.analysis.core import qualname_map
    findings: List[Finding] = []
    for node, qual in qualname_map(tree).items():
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        marked = any(ln in ctx.suppressions.hot_path
                     for ln in range(node.lineno,
                                     node.body[0].lineno + 1))
        if not (marked or _is_hot(path, qual)):
            continue
        t = _Taint(node, path, qual)
        for stmt in node.body:
            t.visit(stmt)
        findings.extend(f for f in t.findings if ctx.enabled(f.rule))
    return findings
