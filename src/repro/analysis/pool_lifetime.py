"""HL4xx — PagePool allocation lifetimes over ``serving/``.

Pages handed out by ``alloc``/``alloc_pages``/``fork``/``adopt_prefix``
must reach an owner that a later ``free_seq``/``truncate_seq``/``release``
can find — on *every* path, including exception edges.  An allocation
that escapes neither into a field/container nor back to the caller, or
that is live when an unguarded ``raise`` fires, leaks pool pages until
the watchdog trips at 3 a.m.

Abstract interpretation over each function body: an alloc-family call
creates an *unpublished* allocation keyed by the root variable of its
seq-id argument.  Publication = storing into an attribute/subscript
mentioning that root, appending it to a container, or returning/yielding
an expression that mentions it.  Release-family calls retire it.

* HL401 ``leak-on-raise``: a ``raise`` (outside a try whose handlers or
  ``finally`` release) while an allocation is unreleased.
* HL402 ``unpublished-alloc``: function exit with an allocation that was
  never published or released.

Branches union their effects (may-leak); loop bodies run twice for
loop-carried state; a ``try`` whose handler/finally contains a
release-family call protects its body.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Set

from repro.analysis.core import (Finding, PassContext, dotted_name,
                                 qualname_map)

RULES = {
    "HL401": "pool allocation may leak on an exception path "
             "(release in a finally/handler, or allocate later)",
    "HL402": "pool allocation never published or released on some path",
}

ALLOC_METHODS = {"alloc", "alloc_pages", "fork", "adopt_prefix"}
RELEASE_METHODS = {"free_seq", "truncate_seq", "release", "free",
                   "release_seq", "drop"}


@dataclass
class _Alloc:
    root: Optional[str]     # root Name of the seq-id argument
    line: int
    col: int
    method: str
    published: bool = False


def _call_method(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _root_name(node: ast.AST) -> Optional[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            return sub.id
    return None


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class _Interp:
    def __init__(self, path: str, qual: str):
        self.path = path
        self.qual = qual
        self.live: List[_Alloc] = []
        self.findings: List[Finding] = []
        self.protected = 0      # depth of trys with releasing handlers

    # ------------------------------------------------------------------
    def _allocs_in(self, node: ast.AST) -> List[_Alloc]:
        out = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) \
                    and _call_method(sub) in ALLOC_METHODS \
                    and isinstance(sub.func.value, (ast.Attribute,
                                                    ast.Name)):
                # require a pool-ish receiver: x.alloc_pages / self.pool.*
                recv = dotted_name(sub.func.value)
                if not recv:
                    continue
                root = _root_name(sub.args[0]) if sub.args else None
                out.append(_Alloc(root, sub.lineno, sub.col_offset,
                                  _call_method(sub)))
        return out

    def _releases_in(self, node: ast.AST) -> List[Optional[str]]:
        out = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) \
                    and _call_method(sub) in RELEASE_METHODS \
                    and isinstance(sub.func, ast.Attribute) \
                    and isinstance(sub.func.value, (ast.Attribute,
                                                    ast.Name)):
                out.append(_root_name(sub.args[0]) if sub.args else None)
        return out

    def _apply_releases(self, roots: List[Optional[str]]) -> None:
        for r in roots:
            if r is None:
                self.live.clear()       # conservative: releases all
            else:
                self.live = [a for a in self.live
                             if a.root is not None and a.root != r]

    def _publish(self, names: Set[str], publish_all: bool = False) -> None:
        for a in self.live:
            if publish_all or (a.root is not None and a.root in names):
                a.published = True

    # ------------------------------------------------------------------
    def _emit(self, rule: str, a: _Alloc, why: str) -> None:
        self.findings.append(Finding(
            rule, self.path, a.line, a.col,
            f"{a.method}() {why}", self.qual))

    def exec_body(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self.exec_stmt(stmt)

    def exec_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.Raise):
            if self.protected == 0:
                for a in self.live:
                    self._emit("HL401", a,
                               "may leak: raise reached while the "
                               "allocation is unreleased and no "
                               "handler/finally releases it")
            self.live = []      # path ends here
            return
        if isinstance(stmt, (ast.Return,)):
            if stmt.value is not None:
                names = _names_in(stmt.value)
                has_alloc_call = any(True for _ in self._allocs_in(
                    stmt.value))
                self._publish(names, publish_all=has_alloc_call)
                # `return self.alloc_pages(...)`: hands pages straight
                # to the caller — published by construction
            self._finish_path()
            self.live = []
            return
        if isinstance(stmt, ast.Try):
            releasing = any(self._releases_in(h) for h in stmt.handlers) \
                or bool(self._releases_in(ast.Module(
                    body=stmt.finalbody, type_ignores=[])))
            if releasing:
                self.protected += 1
            self.exec_body(stmt.body)
            if releasing:
                self.protected -= 1
            for h in stmt.handlers:
                self.exec_body(h.body)
            self.exec_body(stmt.orelse)
            self.exec_body(stmt.finalbody)
            if releasing:
                # handler/finally released on the exception edge; treat
                # the same roots as released on the fallthrough too
                rel = []
                for h in stmt.handlers:
                    rel.extend(self._releases_in(h))
                rel.extend(self._releases_in(ast.Module(
                    body=stmt.finalbody, type_ignores=[])))
                self._apply_releases(rel)
            return
        if isinstance(stmt, ast.If):
            saved = [_Alloc(a.root, a.line, a.col, a.method, a.published)
                     for a in self.live]
            self.exec_body(stmt.body)
            then_live = self.live
            self.live = saved
            self.exec_body(stmt.orelse)
            # union of may-live allocations from both branches
            seen = {(a.line, a.col) for a in self.live}
            for a in then_live:
                if (a.line, a.col) not in seen:
                    self.live.append(a)
            return
        if isinstance(stmt, (ast.For, ast.While)):
            for _ in range(2):
                self.exec_body(stmt.body)
            self.exec_body(stmt.orelse)
            return
        if isinstance(stmt, ast.With):
            self.exec_body(stmt.body)
            return

        # --- straight-line statement: releases, allocs, publications ---
        self._apply_releases(self._releases_in(stmt))
        new_allocs = self._allocs_in(stmt)

        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
            value = stmt.value
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
            value = stmt.value
        else:
            targets, value = [], getattr(stmt, "value", None)

        # an alloc whose result is bound (t = pool.alloc(...)) publishes
        # when that binding later escapes; binding to a plain local is
        # not yet publication — but storing into self.x / d[k] is.
        for a in new_allocs:
            # double-alloc for the same root without release in between
            for prev in self.live:
                if prev.root is not None and prev.root == a.root \
                        and not prev.published:
                    self._emit("HL402", prev,
                               "overlapping allocation for the same "
                               "sequence id without an intervening "
                               "release")
            self.live.append(a)

        store_names: Set[str] = set()
        publish_all = False
        for t in targets:
            if isinstance(t, (ast.Attribute, ast.Subscript)):
                store_names |= _names_in(t)
                if value is not None and new_allocs \
                        and any(id(c) in {id(x) for x in ast.walk(value)}
                                for c in [value]):
                    publish_all = True      # self.t[...] = pool.alloc(...)
                if value is not None:
                    store_names |= _names_in(value)
        # method calls that stash the table: x.append(t) / x.extend(...)
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Call) \
                    and isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in ("append", "extend", "add",
                                          "update", "setdefault"):
                for arg in sub.args:
                    store_names |= _names_in(arg)
        if store_names or publish_all:
            self._publish(store_names, publish_all=publish_all)

    def _finish_path(self) -> None:
        for a in self.live:
            if not a.published:
                self._emit("HL402", a,
                           "result never published (stored/returned) or "
                           "released before function exit")

    def finish(self) -> None:
        self._finish_path()


def run(tree: ast.AST, src: str, path: str, ctx: PassContext) -> List[Finding]:
    if not (ctx.enabled("HL401") or ctx.enabled("HL402")):
        return []
    if not any(m in src for m in ALLOC_METHODS):
        return []
    findings: List[Finding] = []
    for node, qual in qualname_map(tree).items():
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        interp = _Interp(path, qual)
        interp.exec_body(node.body)
        interp.finish()
        findings.extend(f for f in interp.findings if ctx.enabled(f.rule))
    # loops run bodies twice; If-union can duplicate — dedupe
    seen, out = set(), []
    for f in findings:
        k = (f.rule, f.path, f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
