"""hornshape symbolic core: expressions, abstract domains, interpreter.

Three layers, all jax-free:

1. **Symbolic expressions** — ``Sym`` (integer) / ``SymBool`` trees built by
   operator overloading, so a Pallas ``index_map`` lambda evaluated on
   ``Sym`` grid variables yields the exact expression the DMA engine will
   compute (including ``jnp.where``/``jnp.minimum`` clamps and block-table
   ``lookup`` gathers).
2. **Abstract domains** — interval bounds via affine normalization (so
   ``g - g`` cancels exactly) plus a congruence domain ``(m, r)`` (value
   ≡ r mod m; ``m == 0`` means the exact constant ``r``).  ``prove``
   decides a ``SymBool`` three-valued: True / False / None (inconclusive).
3. **A restricted-Python mini-interpreter** — abstractly executes a kernel
   *wrapper* function (the Python that builds grids and BlockSpecs) on
   ``FakeArray``/``Table`` arguments, intercepting ``pl.pallas_call`` to
   capture the full launch geometry without ever importing jax.  The
   captured ``index_map`` closures are then re-entered with ``Sym`` grid
   indices by ``blockspec_verify``.

Soundness contract: a ``prove(...) is True`` verdict is a proof over *all*
concrete grid points (interval/congruence are over-approximations); the
exact ground truth for any geometry is ``concrete_all`` enumeration, which
``blockspec_verify`` falls back to whenever the symbolic layer is
inconclusive.  ``Table`` lookups contribute their declared value range
``[lo, hi]``; enumeration substitutes both endpoints.
"""
from __future__ import annotations

import ast
import math
from typing import Dict, List, Optional, Tuple

_INT_OPS = ("var", "const", "add", "sub", "mul", "neg", "floordiv", "mod",
            "min", "max", "where", "lookup")
_BOOL_OPS = ("lt", "le", "gt", "ge", "eq", "ne", "and", "or", "not", "bconst")


class AnalysisError(Exception):
    """The mini-interpreter hit code it cannot soundly abstract."""


# --------------------------------------------------------------------------
# expression nodes
# --------------------------------------------------------------------------
class Sym:
    """Integer-valued symbolic expression.  Identity-hashed: use ``seq``
    for structural equality, ``==`` builds a SymBool."""
    __slots__ = ("op", "args")

    def __init__(self, op: str, *args):
        assert op in _INT_OPS, op
        self.op = op
        self.args = args

    # -- construction helpers ------------------------------------------
    def __add__(self, o):
        return _binop("add", self, o)

    __radd__ = __add__

    def __sub__(self, o):
        return _binop("sub", self, o)

    def __rsub__(self, o):
        return _binop("sub", o, self)

    def __mul__(self, o):
        return _binop("mul", self, o)

    __rmul__ = __mul__

    def __floordiv__(self, o):
        return _binop("floordiv", self, o)

    def __rfloordiv__(self, o):
        return _binop("floordiv", o, self)

    def __mod__(self, o):
        return _binop("mod", self, o)

    def __rmod__(self, o):
        return _binop("mod", o, self)

    def __neg__(self):
        return Sym("neg", self)

    def __lt__(self, o):
        return SymBool("lt", self, sym(o))

    def __le__(self, o):
        return SymBool("le", self, sym(o))

    def __gt__(self, o):
        return SymBool("gt", self, sym(o))

    def __ge__(self, o):
        return SymBool("ge", self, sym(o))

    def __eq__(self, o):  # noqa: D105 — symbolic equality, not identity
        return SymBool("eq", self, sym(o))

    def __ne__(self, o):
        return SymBool("ne", self, sym(o))

    __hash__ = object.__hash__

    def __repr__(self):
        if self.op == "var":
            return self.args[0]
        if self.op == "const":
            return str(self.args[0])
        if self.op == "lookup":
            table, idx = self.args
            return f"{table.name}[{', '.join(map(repr, idx))}]"
        return f"{self.op}({', '.join(map(repr, self.args))})"


class SymBool:
    __slots__ = ("op", "args")

    def __init__(self, op: str, *args):
        assert op in _BOOL_OPS, op
        self.op = op
        self.args = args

    def __and__(self, o):
        return SymBool("and", self, _symbool(o))

    __rand__ = __and__

    def __or__(self, o):
        return SymBool("or", self, _symbool(o))

    __ror__ = __or__

    def __invert__(self):
        return SymBool("not", self)

    def __bool__(self):
        raise AnalysisError(
            "symbolic boolean used in concrete control flow — use "
            "jnp.where / s_where instead of `if`")

    def __repr__(self):
        return f"{self.op}({', '.join(map(repr, self.args))})"


def sym(x) -> Sym:
    if isinstance(x, Sym):
        return x
    if isinstance(x, bool):
        return Sym("const", int(x))
    if isinstance(x, int):
        return Sym("const", x)
    if isinstance(x, float) and x == int(x):
        return Sym("const", int(x))
    raise AnalysisError(f"cannot lift {x!r} into a symbolic integer")


def _symbool(x) -> SymBool:
    if isinstance(x, SymBool):
        return x
    if isinstance(x, bool):
        return SymBool("bconst", x)
    raise AnalysisError(f"cannot lift {x!r} into a symbolic boolean")


def _binop(op: str, a, b):
    a, b = sym(a), sym(b)
    if a.op == "const" and b.op == "const":
        x, y = a.args[0], b.args[0]
        return Sym("const", {
            "add": lambda: x + y, "sub": lambda: x - y,
            "mul": lambda: x * y, "floordiv": lambda: x // y,
            "mod": lambda: x % y, "min": lambda: min(x, y),
            "max": lambda: max(x, y)}[op]())
    return Sym(op, a, b)


def var(name: str) -> Sym:
    return Sym("var", name)


def const(v: int) -> Sym:
    return Sym("const", int(v))


def s_min(a, b) -> Sym:
    return _binop("min", a, b)


def s_max(a, b) -> Sym:
    return _binop("max", a, b)


def s_where(cond, a, b) -> Sym:
    if isinstance(cond, bool):
        return sym(a) if cond else sym(b)
    return Sym("where", _symbool(cond), sym(a), sym(b))


def s_clip(x, lo, hi) -> Sym:
    return s_min(s_max(x, lo), hi)


def seq(a, b) -> bool:
    """Structural equality (``==`` on Sym builds a SymBool instead)."""
    a, b = sym(a), sym(b)
    if a.op != b.op:
        return False
    if a.op in ("var", "const"):
        return a.args == b.args
    if a.op == "lookup":
        ta, ia = a.args
        tb, ib = b.args
        return ta is tb and len(ia) == len(ib) \
            and all(seq(x, y) for x, y in zip(ia, ib))
    return len(a.args) == len(b.args) \
        and all(seq(x, y) for x, y in zip(a.args, b.args))


def free_vars(e) -> set:
    out: set = set()
    stack = [e]
    while stack:
        n = stack.pop()
        if isinstance(n, Sym):
            if n.op == "var":
                out.add(n.args[0])
            elif n.op == "lookup":
                stack.extend(n.args[1])
            else:
                stack.extend(n.args)
        elif isinstance(n, SymBool):
            stack.extend(a for a in n.args if isinstance(a, (Sym, SymBool)))
    return out


def lookups_in(e) -> List[Sym]:
    """Every lookup node anywhere in ``e`` (including where-conditions)."""
    out: List[Sym] = []
    stack = [e]
    while stack:
        n = stack.pop()
        if isinstance(n, Sym):
            if n.op == "lookup":
                out.append(n)
                stack.extend(n.args[1])
            else:
                stack.extend(n.args)
        elif isinstance(n, SymBool):
            stack.extend(a for a in n.args if isinstance(a, (Sym, SymBool)))
    return out


# --------------------------------------------------------------------------
# abstract values the interpreter manipulates
# --------------------------------------------------------------------------
class Table:
    """Scalar-prefetch operand (block table / lengths): an int array whose
    *contents* are abstract but bounded to the declared ``[lo, hi]``."""

    def __init__(self, name: str, shape: Tuple[int, ...],
                 lo: int = 0, hi: int = 0):
        self.name = name
        self.shape = tuple(shape)
        self.lo, self.hi = lo, hi

    @property
    def ndim(self):
        return len(self.shape)

    def astype(self, _dtype):
        return self

    def __getitem__(self, idx):
        idx = idx if isinstance(idx, tuple) else (idx,)
        if len(idx) > len(self.shape):
            raise AnalysisError(
                f"table {self.name} indexed with {len(idx)} subscripts "
                f"but has rank {len(self.shape)}")
        return Sym("lookup", self, tuple(sym(i) for i in idx))

    def __repr__(self):
        return f"Table({self.name}, {self.shape}, [{self.lo},{self.hi}])"


class FakeArray:
    """Shape/dtype-only stand-in for a jax array."""

    def __init__(self, shape: Tuple[int, ...], dtype: str = "float32"):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype

    @property
    def ndim(self):
        return len(self.shape)

    @property
    def size(self):
        return math.prod(self.shape)

    def astype(self, dtype):
        return FakeArray(self.shape, _dtype_name(dtype))

    def reshape(self, *dims):
        if len(dims) == 1 and isinstance(dims[0], (tuple, list)):
            dims = tuple(dims[0])
        dims = tuple(int(d) for d in dims)
        if -1 in dims:
            known = math.prod(d for d in dims if d != -1)
            dims = tuple(self.size // known if d == -1 else d for d in dims)
        if math.prod(dims) != self.size:
            raise AnalysisError(
                f"reshape {self.shape} -> {dims}: element count mismatch")
        return FakeArray(dims, self.dtype)

    def transpose(self, *perm):
        if len(perm) == 1 and isinstance(perm[0], (tuple, list)):
            perm = tuple(perm[0])
        if sorted(perm) != list(range(self.ndim)):
            raise AnalysisError(f"bad transpose {perm} for rank {self.ndim}")
        return FakeArray(tuple(self.shape[p] for p in perm), self.dtype)

    def __repr__(self):
        return f"FakeArray({self.shape}, {self.dtype})"


def _dtype_name(d) -> str:
    if isinstance(d, str):
        return d.split(".")[-1]
    if isinstance(d, FakeArray):
        return d.dtype
    return str(d)


class BlockSpecV:
    def __init__(self, block_shape=None, index_map=None, memory_space=None):
        self.block_shape = tuple(block_shape) if block_shape is not None \
            else None
        self.index_map = index_map
        self.memory_space = memory_space


class GridSpecV:
    def __init__(self, num_scalar_prefetch=0, grid=(), in_specs=None,
                 out_specs=None, scratch_shapes=None):
        self.num_scalar_prefetch = num_scalar_prefetch
        self.grid = tuple(grid)
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.scratch_shapes = scratch_shapes


class ScratchV:
    def __init__(self, shape, dtype="float32"):
        self.shape = tuple(shape)
        self.dtype = _dtype_name(dtype)


class ShapeDtypeV:
    def __init__(self, shape, dtype="float32"):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = _dtype_name(dtype)


class Capture:
    """One pl.pallas_call site: launch kwargs + the concrete call args."""

    def __init__(self, kernel, kwargs, lineno):
        self.kernel = kernel
        self.kwargs = kwargs
        self.lineno = lineno
        self.args: list = []


class PallasCaller:
    def __init__(self, capture: Capture, sink: list):
        self.capture = capture
        self.sink = sink

    def __call__(self, *args):
        self.capture.args = list(args)
        self.sink.append(self.capture)
        out_shape = self.capture.kwargs.get("out_shape")
        if isinstance(out_shape, (list, tuple)):
            return [FakeArray(o.shape, o.dtype) for o in out_shape]
        if out_shape is None:
            raise AnalysisError("pallas_call without out_shape")
        return FakeArray(out_shape.shape, out_shape.dtype)


# --------------------------------------------------------------------------
# interval bounds via affine normalization
# --------------------------------------------------------------------------
_NEG = -(1 << 62)
_POS = 1 << 62

Env = Dict[str, Tuple[int, int]]   # var name -> inclusive range


def _linearize(e: Sym):
    """-> (const, {var: coeff}, [(coeff, opaque Sym)]) with cancellation."""
    if e.op == "const":
        return e.args[0], {}, []
    if e.op == "var":
        return 0, {e.args[0]: 1}, []
    if e.op == "neg":
        c, v, o = _linearize(e.args[0])
        return -c, {k: -x for k, x in v.items()}, [(-x, a) for x, a in o]
    if e.op in ("add", "sub"):
        c1, v1, o1 = _linearize(e.args[0])
        c2, v2, o2 = _linearize(e.args[1])
        s = 1 if e.op == "add" else -1
        v = dict(v1)
        for k, x in v2.items():
            v[k] = v.get(k, 0) + s * x
        return (c1 + s * c2, {k: x for k, x in v.items() if x},
                o1 + [(s * x, a) for x, a in o2])
    if e.op == "mul":
        for a, b in (e.args, e.args[::-1]):
            ca, va, oa = _linearize(a)
            if not va and not oa:                  # pure constant side
                cb, vb, ob = _linearize(b)
                return (ca * cb, {k: ca * x for k, x in vb.items() if ca * x},
                        [(ca * x, at) for x, at in ob if ca * x])
    return 0, {}, [(1, e)]


def _scaled(coeff: int, lo: int, hi: int) -> Tuple[int, int]:
    a, b = coeff * lo, coeff * hi
    return (min(a, b), max(a, b))


def bounds(e, env: Env) -> Tuple[int, int]:
    """Inclusive interval of ``e`` over ``env`` var ranges (sound)."""
    e = sym(e)
    c, vs, ops = _linearize(e)
    lo = hi = c
    for name, coeff in vs.items():
        if name not in env:
            return (_NEG, _POS)
        vlo, vhi = env[name]
        a, b = _scaled(coeff, vlo, vhi)
        lo, hi = lo + a, hi + b
    for coeff, atom in ops:
        alo, ahi = _atom_bounds(atom, env)
        if alo <= _NEG or ahi >= _POS:
            return (_NEG, _POS)
        a, b = _scaled(coeff, alo, ahi)
        lo, hi = lo + a, hi + b
    return lo, hi


def _atom_bounds(e: Sym, env: Env) -> Tuple[int, int]:
    if e.op == "lookup":
        t = e.args[0]
        return (t.lo, t.hi)
    if e.op in ("min", "max"):
        a = bounds(e.args[0], env)
        b = bounds(e.args[1], env)
        if e.op == "min":
            return (min(a[0], b[0]), min(a[1], b[1]))
        return (max(a[0], b[0]), max(a[1], b[1]))
    if e.op == "where":
        cond, x, y = e.args
        v = prove(cond, env)
        if v is True:
            return bounds(x, env)
        if v is False:
            return bounds(y, env)
        a, b = bounds(x, env), bounds(y, env)
        return (min(a[0], b[0]), max(a[1], b[1]))
    if e.op == "floordiv":
        (alo, ahi) = bounds(e.args[0], env)
        d = e.args[1]
        if d.op == "const" and d.args[0] > 0 and alo > _NEG and ahi < _POS:
            return (alo // d.args[0], ahi // d.args[0])
        return (_NEG, _POS)
    if e.op == "mod":
        (alo, ahi) = bounds(e.args[0], env)
        d = e.args[1]
        if d.op == "const" and d.args[0] > 0:
            dd = d.args[0]
            if alo > _NEG and ahi < _POS and alo // dd == ahi // dd:
                return (alo % dd, ahi % dd)   # one period: exact
            if alo >= 0:
                return (0, dd - 1)
            return (-(dd - 1), dd - 1)
        return (_NEG, _POS)
    if e.op == "mul":
        a, b = bounds(e.args[0], env), bounds(e.args[1], env)
        if min(a + b) <= _NEG or max(a + b) >= _POS:
            return (_NEG, _POS)
        corners = [x * y for x in a for y in b]
        return (min(corners), max(corners))
    # add/sub/neg atoms never reach here (linearized away); be safe:
    return bounds(e, env) if e.op in ("add", "sub", "neg", "const", "var") \
        else (_NEG, _POS)


# --------------------------------------------------------------------------
# congruence domain: value ≡ r (mod m); m == 0 means exactly r
# --------------------------------------------------------------------------
def congruence(e, env: Env) -> Tuple[int, int]:
    e = sym(e)
    if e.op == "const":
        return (0, e.args[0])
    if e.op == "var":
        lo, hi = env.get(e.args[0], (_NEG, _POS))
        if lo == hi:
            return (0, lo)
        return (1, 0)
    if e.op == "neg":
        m, r = congruence(e.args[0], env)
        return (0, -r) if m == 0 else (m, (-r) % m)
    if e.op in ("add", "sub"):
        m1, r1 = congruence(e.args[0], env)
        m2, r2 = congruence(e.args[1], env)
        s = 1 if e.op == "add" else -1
        if m1 == 0 and m2 == 0:
            return (0, r1 + s * r2)
        g = math.gcd(m1, m2)
        if g == 0:
            g = max(m1, m2)
        if g <= 1:
            return (1, 0)
        return (g, (r1 + s * r2) % g)
    if e.op == "mul":
        m1, r1 = congruence(e.args[0], env)
        m2, r2 = congruence(e.args[1], env)
        if m1 == 0 and m2 == 0:
            return (0, r1 * r2)
        if m1 == 0:
            m1, r1, m2, r2 = m2, r2, m1, r1
        # now m1 > 0; multiply by exact constant r2?
        if m2 == 0:
            c = r2
            if c == 0:
                return (0, 0)
            mm = abs(m1 * c)
            return (mm, (r1 * c) % mm) if mm > 1 else (1, 0)
        return (1, 0)
    if e.op == "floordiv":
        d = e.args[1]
        if d.op == "const" and d.args[0] > 0:
            dd = d.args[0]
            m, r = congruence(e.args[0], env)
            if m == 0:
                return (0, r // dd)
            if m % dd == 0 and 0 <= r < m:
                mm = m // dd
                return (mm, (r // dd) % mm) if mm > 1 else (1, 0)
        return (1, 0)
    if e.op == "mod":
        d = e.args[1]
        if d.op == "const" and d.args[0] > 0:
            dd = d.args[0]
            m, r = congruence(e.args[0], env)
            if m == 0:
                return (0, r % dd)
            if m % dd == 0:
                return (0, r % dd)          # x = m k + r, d | m -> x%d = r%d
            if m > 1 and dd % m == 0:
                return (m, r % m)
        return (1, 0)
    return (1, 0)   # min/max/where/lookup: no congruence info


# --------------------------------------------------------------------------
# three-valued proving
# --------------------------------------------------------------------------
def prove(b, env: Env) -> Optional[bool]:
    """True: holds for every valuation; False: fails for every valuation;
    None: inconclusive (mixed or unknown)."""
    b = _symbool(b)
    if b.op == "bconst":
        return b.args[0]
    if b.op == "not":
        v = prove(b.args[0], env)
        return None if v is None else (not v)
    if b.op == "and":
        l, r = prove(b.args[0], env), prove(b.args[1], env)
        if l is False or r is False:
            return False
        if l is True and r is True:
            return True
        return None
    if b.op == "or":
        l, r = prove(b.args[0], env), prove(b.args[1], env)
        if l is True or r is True:
            return True
        if l is False and r is False:
            return False
        return None
    a, c = sym(b.args[0]), sym(b.args[1])
    diff = Sym("sub", a, c)
    lo, hi = bounds(diff, env)
    unb = lo <= _NEG or hi >= _POS
    if b.op in ("lt", "gt", "le", "ge"):
        if b.op in ("gt", "ge"):
            lo, hi = -hi, -lo
            strict = b.op == "gt"
        else:
            strict = b.op == "lt"
        if unb:
            return None
        if (hi < 0) if strict else (hi <= 0):
            return True
        if (lo >= 0) if strict else (lo > 0):
            return False
        return None
    if b.op in ("eq", "ne"):
        want = b.op == "eq"
        if not unb:
            if lo == hi == 0:
                return want
            if lo > 0 or hi < 0:
                return not want
        m, r = congruence(diff, env)
        if m == 0:
            return want if r == 0 else (not want)
        if m > 1 and r != 0:
            return not want          # diff ≡ r ≠ 0 (mod m): never zero
        return None
    return None


# --------------------------------------------------------------------------
# exact concrete enumeration (the ground truth the property test trusts)
# --------------------------------------------------------------------------
_ENUM_CAP = 64


def concrete_all(e, point: Dict[str, int]) -> frozenset:
    """All values ``e`` can take at the concrete grid ``point``; lookups
    contribute their table's declared endpoints {lo, hi} (exact for the
    monotone clamp/guard uses the kernels make of them)."""
    e = sym(e) if not isinstance(e, SymBool) else e
    if isinstance(e, SymBool):
        return _concrete_bool(e, point)
    if e.op == "const":
        return frozenset((e.args[0],))
    if e.op == "var":
        if e.args[0] not in point:
            raise AnalysisError(f"unbound var {e.args[0]} in enumeration")
        return frozenset((point[e.args[0]],))
    if e.op == "lookup":
        t = e.args[0]
        for i, ix in enumerate(e.args[1]):
            for v in concrete_all(ix, point):
                if not 0 <= v < t.shape[i]:
                    # OOB lookups surface through the in-bounds obligations;
                    # value-wise the read is unconstrained
                    return frozenset((t.lo, t.hi))
        return frozenset((t.lo, t.hi)) if t.lo != t.hi \
            else frozenset((t.lo,))
    if e.op == "where":
        cond, a, b = e.args
        out = set()
        cv = _concrete_bool(cond, point)
        if True in cv:
            out |= concrete_all(a, point)
        if False in cv:
            out |= concrete_all(b, point)
        return _cap(out)
    if e.op == "neg":
        return _cap({-v for v in concrete_all(e.args[0], point)})
    fns = {"add": lambda x, y: x + y, "sub": lambda x, y: x - y,
           "mul": lambda x, y: x * y, "floordiv": lambda x, y: x // y,
           "mod": lambda x, y: x % y, "min": min, "max": max}
    f = fns[e.op]
    out = set()
    for x in concrete_all(e.args[0], point):
        for y in concrete_all(e.args[1], point):
            out.add(f(x, y))
    return _cap(out)


def _cap(s: set) -> frozenset:
    if len(s) > _ENUM_CAP:
        raise AnalysisError(f"value set exploded past {_ENUM_CAP}")
    return frozenset(s)


def _concrete_bool(b: SymBool, point) -> frozenset:
    if b.op == "bconst":
        return frozenset((b.args[0],))
    if b.op == "not":
        return frozenset(not v for v in _concrete_bool(b.args[0], point))
    if b.op in ("and", "or"):
        f = (lambda x, y: x and y) if b.op == "and" else (lambda x, y: x or y)
        out = set()
        for x in _concrete_bool(b.args[0], point):
            for y in _concrete_bool(b.args[1], point):
                out.add(f(x, y))
        return frozenset(out)
    fns = {"lt": lambda x, y: x < y, "le": lambda x, y: x <= y,
           "gt": lambda x, y: x > y, "ge": lambda x, y: x >= y,
           "eq": lambda x, y: x == y, "ne": lambda x, y: x != y}
    f = fns[b.op]
    out = set()
    for x in concrete_all(sym(b.args[0]), point):
        for y in concrete_all(sym(b.args[1]), point):
            out.add(f(x, y))
    return frozenset(out)


# --------------------------------------------------------------------------
# the mini-interpreter
# --------------------------------------------------------------------------
class _Return(Exception):
    def __init__(self, value):
        self.value = value


class Closure:
    def __init__(self, node, env: "Frame", interp: "Interp", name=""):
        self.node = node            # FunctionDef | Lambda
        self.env = env
        self.interp = interp
        self.name = name or getattr(node, "name", "<lambda>")

    def __call__(self, *args, **kwargs):
        return self.interp.call(self, args, kwargs)


class Partial:
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, tuple(args), dict(kwargs)

    def __call__(self, *args, **kwargs):
        kw = dict(self.kwargs)
        kw.update(kwargs)
        return self.fn(*self.args, *args, **kw)


_DTYPE_NAMES = {
    "float64", "float32", "float16", "bfloat16", "int64", "int32", "int16",
    "int8", "int4", "uint8", "uint32", "bool_",
}


class NS:
    """Intrinsic namespace (jnp / jax / pl / pltpu / functools / lax)."""

    def __init__(self, name: str, table: Dict[str, object]):
        self._name = name
        self._table = table

    def __getattr__(self, attr):
        if attr.startswith("_"):
            raise AttributeError(attr)
        if attr in self._table:
            return self._table[attr]
        if attr in _DTYPE_NAMES:
            return attr                 # bare dtype name: comparable
        return f"{self._name}.{attr}"   # memory-space / misc token


class Frame:
    def __init__(self, parent: Optional["Frame"] = None):
        self.vars: Dict[str, object] = {}
        self.parent = parent

    def get(self, name):
        f = self
        while f is not None:
            if name in f.vars:
                return f.vars[name]
            f = f.parent
        raise AnalysisError(f"unbound name {name!r}")

    def has(self, name):
        f = self
        while f is not None:
            if name in f.vars:
                return True
            f = f.parent
        return False

    def set(self, name, value):
        self.vars[name] = value


def _jnp_where(cond, a, b):
    if isinstance(cond, bool):
        return a if cond else b
    return s_where(cond, a, b)


def _jnp_minimum(a, b):
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        return min(a, b)
    return s_min(a, b)


def _jnp_maximum(a, b):
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        return max(a, b)
    return s_max(a, b)


def _jnp_clip(x, lo, hi):
    return _jnp_minimum(_jnp_maximum(x, lo), hi)


def _jit(fn=None, **_kw):
    if fn is None:
        return lambda f: f
    return fn


def _shape_struct(shape, dtype):
    return ShapeDtypeV(shape, dtype)


class Interp:
    """Abstract interpreter for kernel-wrapper Python.

    Executes module top level (constants + defs; imports skipped), then
    ``call``-s a wrapper on ``FakeArray``/``Table`` args.  Every
    ``pl.pallas_call`` invocation lands in ``self.captures``.
    """

    def __init__(self):
        self.captures: List[Capture] = []
        self.globals = Frame()
        jnp_tbl = {
            "where": _jnp_where, "minimum": _jnp_minimum,
            "maximum": _jnp_maximum, "clip": _jnp_clip,
        }
        lax_tbl: Dict[str, object] = {}
        jax_tbl = {
            "jit": _jit,
            "ShapeDtypeStruct": _shape_struct,
            "numpy": NS("jnp", jnp_tbl),
            "lax": NS("lax", lax_tbl),
        }
        pl_tbl = {
            "BlockSpec": BlockSpecV,
            "pallas_call": self._pallas_call,
        }
        pltpu_tbl = {
            "PrefetchScalarGridSpec": GridSpecV,
            "VMEM": ScratchV,
            "SMEM": "pltpu.SMEM",
            "ANY": "pltpu.ANY",
            "TPUCompilerParams": lambda **kw: dict(kw),
        }
        ft_tbl = {"partial": lambda fn, *a, **kw: Partial(fn, a, kw)}
        self.namespaces = {
            "jnp": NS("jnp", jnp_tbl), "jax": NS("jax", jax_tbl),
            "lax": NS("lax", lax_tbl), "pl": NS("pl", pl_tbl),
            "pltpu": NS("pltpu", pltpu_tbl),
            "functools": NS("functools", ft_tbl),
            "np": NS("np", {}), "partial": ft_tbl["partial"],
        }
        self.builtins = {
            "range": range, "len": len, "max": max, "min": min, "abs": abs,
            "int": int, "sum": sum, "sorted": sorted, "tuple": tuple,
            "list": list, "enumerate": enumerate, "zip": zip,
            "ValueError": ValueError, "AssertionError": AssertionError,
            "True": True, "False": False, "None": None,
        }
        self._lineno = 0

    # -- intrinsics ----------------------------------------------------
    def _pallas_call(self, kernel, **kwargs):
        cap = Capture(kernel, kwargs, self._lineno)
        return PallasCaller(cap, self.captures)

    # -- module / function entry ---------------------------------------
    def run_module(self, tree: ast.Module) -> Frame:
        env = Frame(self.globals)
        for name, ns in self.namespaces.items():
            env.set(name, ns)
        for stmt in tree.body:
            self._stmt(stmt, env)
        return env

    def call(self, fn, args=(), kwargs=None):
        kwargs = kwargs or {}
        while isinstance(fn, Partial):
            kwargs = {**fn.kwargs, **kwargs}
            args = (*fn.args, *args)
            fn = fn.fn
        if isinstance(fn, Closure):
            return self._call_closure(fn, args, kwargs)
        if callable(fn):
            return fn(*args, **kwargs)
        raise AnalysisError(f"not callable: {fn!r}")

    def _call_closure(self, cl: Closure, args, kwargs):
        node = cl.node
        frame = Frame(cl.env)
        a = node.args
        names = [p.arg for p in a.posonlyargs + a.args]
        pos = list(args)
        n_named = len(names)
        bound: Dict[str, object] = {}
        for i, name in enumerate(names):
            if i < len(pos):
                bound[name] = pos[i]
        extra = pos[n_named:]
        if a.vararg is not None:
            bound[a.vararg.arg] = tuple(extra)
        elif extra:
            raise AnalysisError(
                f"{cl.name}() takes {n_named} positional args, got "
                f"{len(pos)}")
        kw_names = [p.arg for p in a.kwonlyargs]
        for k, v in kwargs.items():
            if k in names or k in kw_names:
                if k in bound:
                    raise AnalysisError(f"duplicate arg {k!r} to {cl.name}")
                bound[k] = v
            elif a.kwarg is not None:
                bound.setdefault(a.kwarg.arg, {})
                bound[a.kwarg.arg][k] = v
            else:
                raise AnalysisError(f"unexpected kwarg {k!r} to {cl.name}")
        # defaults
        defaults = a.defaults
        for i, d in enumerate(defaults):
            name = names[n_named - len(defaults) + i]
            if name not in bound:
                bound[name] = self._expr(d, cl.env)
        for p, d in zip(a.kwonlyargs, a.kw_defaults):
            if p.arg not in bound:
                if d is None:
                    raise AnalysisError(
                        f"missing kwonly arg {p.arg!r} to {cl.name}")
                bound[p.arg] = self._expr(d, cl.env)
        missing = [n for n in names + kw_names if n not in bound]
        if missing:
            raise AnalysisError(f"missing args {missing} to {cl.name}")
        for k, v in bound.items():
            frame.set(k, v)
        if isinstance(node, ast.Lambda):
            return self._expr(node.body, frame)
        try:
            for stmt in node.body:
                self._stmt(stmt, frame)
        except _Return as r:
            return r.value
        return None

    # -- statements ----------------------------------------------------
    def _stmt(self, node, env: Frame):
        self._lineno = getattr(node, "lineno", self._lineno)
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            return
        if isinstance(node, (ast.FunctionDef,)):
            fn: object = Closure(node, env, self, node.name)
            for dec in reversed(node.decorator_list):
                fn = self.call(self._expr(dec, env), (fn,))
            env.set(node.name, fn)
            return
        if isinstance(node, ast.ClassDef):
            return                                    # not needed; skip
        if isinstance(node, ast.Assign):
            value = self._expr(node.value, env)
            for tgt in node.targets:
                self._assign(tgt, value, env)
            return
        if isinstance(node, ast.AnnAssign):
            if node.value is not None:
                self._assign(node.target, self._expr(node.value, env), env)
            return
        if isinstance(node, ast.AugAssign):
            if not isinstance(node.target, ast.Name):
                raise AnalysisError("augmented assign to non-name")
            cur = env.get(node.target.id)
            val = self._expr(node.value, env)
            env.set(node.target.id, self._binary(node.op, cur, val))
            return
        if isinstance(node, ast.Expr):
            self._expr(node.value, env)
            return
        if isinstance(node, ast.Return):
            raise _Return(self._expr(node.value, env)
                          if node.value is not None else None)
        if isinstance(node, ast.If):
            body = node.body if self._concrete_cond(node.test, env) \
                else node.orelse
            for s in body:
                self._stmt(s, env)
            return
        if isinstance(node, ast.While):
            guard = 0
            while self._concrete_cond(node.test, env):
                for s in node.body:
                    self._stmt(s, env)
                guard += 1
                if guard > 10_000:
                    raise AnalysisError("while loop did not terminate")
            return
        if isinstance(node, ast.For):
            it = self._expr(node.iter, env)
            if not isinstance(it, (range, list, tuple)):
                raise AnalysisError(f"cannot iterate {it!r}")
            for v in it:
                self._assign(node.target, v, env)
                for s in node.body:
                    self._stmt(s, env)
            for s in node.orelse:
                self._stmt(s, env)
            return
        if isinstance(node, ast.Assert):
            try:
                ok = self._concrete_cond(node.test, env)
            except AnalysisError:
                return                  # symbolic assert: cannot discharge
            if not ok:
                raise AnalysisError(
                    f"assert failed at line {node.lineno}")
            return
        if isinstance(node, ast.Raise):
            raise AnalysisError(f"raise reached at line {node.lineno}")
        if isinstance(node, ast.Pass):
            return
        if isinstance(node, ast.Global):
            return
        raise AnalysisError(
            f"unsupported statement {type(node).__name__} at line "
            f"{getattr(node, 'lineno', '?')}")

    def _assign(self, tgt, value, env: Frame):
        if isinstance(tgt, ast.Name):
            env.set(tgt.id, value)
            return
        if isinstance(tgt, (ast.Tuple, ast.List)):
            vals = list(value)
            if len(vals) != len(tgt.elts):
                raise AnalysisError(
                    f"unpack mismatch: {len(tgt.elts)} targets, "
                    f"{len(vals)} values")
            for t, v in zip(tgt.elts, vals):
                self._assign(t, v, env)
            return
        if isinstance(tgt, ast.Starred):
            raise AnalysisError("starred assignment unsupported")
        raise AnalysisError(
            f"unsupported assign target {type(tgt).__name__}")

    def _concrete_cond(self, test, env) -> bool:
        v = self._expr(test, env)
        if isinstance(v, (Sym, SymBool)):
            raise AnalysisError(
                f"symbolic condition in concrete control flow at line "
                f"{getattr(test, 'lineno', '?')}")
        return bool(v)

    # -- expressions ---------------------------------------------------
    def _expr(self, node, env: Frame):
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            if env.has(node.id):
                return env.get(node.id)
            if node.id in self.builtins:
                return self.builtins[node.id]
            raise AnalysisError(f"unbound name {node.id!r} at line "
                                f"{getattr(node, 'lineno', '?')}")
        if isinstance(node, ast.Tuple):
            return tuple(self._expr(e, env) for e in node.elts)
        if isinstance(node, ast.List):
            return [self._expr(e, env) for e in node.elts]
        if isinstance(node, ast.Dict):
            return {self._expr(k, env): self._expr(v, env)
                    for k, v in zip(node.keys, node.values)}
        if isinstance(node, ast.BinOp):
            return self._binary(node.op, self._expr(node.left, env),
                                self._expr(node.right, env))
        if isinstance(node, ast.UnaryOp):
            v = self._expr(node.operand, env)
            if isinstance(node.op, ast.USub):
                return -v
            if isinstance(node.op, ast.UAdd):
                return v
            if isinstance(node.op, ast.Not):
                if isinstance(v, SymBool):
                    return ~v
                return not v
            if isinstance(node.op, ast.Invert):
                if isinstance(v, SymBool):
                    return ~v
                return ~v
        if isinstance(node, ast.BoolOp):
            vals = [self._expr(v, env) for v in node.values]
            if any(isinstance(v, (Sym, SymBool)) for v in vals):
                out = _symbool(vals[0]) if not isinstance(vals[0], Sym) \
                    else (sym(vals[0]) != 0)
                for v in vals[1:]:
                    v = _symbool(v) if not isinstance(v, Sym) \
                        else (sym(v) != 0)
                    out = (out & v) if isinstance(node.op, ast.And) \
                        else (out | v)
                return out
            if isinstance(node.op, ast.And):
                out = vals[0]
                for v in vals[1:]:
                    out = out and v
                return out
            out = vals[0]
            for v in vals[1:]:
                out = out or v
            return out
        if isinstance(node, ast.Compare):
            left = self._expr(node.left, env)
            result: object = True
            for op, cmp in zip(node.ops, node.comparators):
                right = self._expr(cmp, env)
                step = self._compare(op, left, right)
                if isinstance(step, SymBool):
                    if result is not True:
                        raise AnalysisError("chained symbolic compare")
                    result = step
                else:
                    if isinstance(result, SymBool):
                        raise AnalysisError("chained symbolic compare")
                    result = result and step
                    if result is False:
                        return False
                left = right
            return result
        if isinstance(node, ast.IfExp):
            return self._expr(node.body, env) \
                if self._concrete_cond(node.test, env) \
                else self._expr(node.orelse, env)
        if isinstance(node, ast.Lambda):
            return Closure(node, env, self)
        if isinstance(node, ast.Call):
            fn = self._expr(node.func, env)
            args = []
            for a in node.args:
                if isinstance(a, ast.Starred):
                    args.extend(self._expr(a.value, env))
                else:
                    args.append(self._expr(a, env))
            kwargs = {}
            for k in node.keywords:
                if k.arg is None:
                    kwargs.update(self._expr(k.value, env))
                else:
                    kwargs[k.arg] = self._expr(k.value, env)
            self._lineno = node.lineno
            return self.call(fn, args, kwargs)
        if isinstance(node, ast.Attribute):
            base = self._expr(node.value, env)
            return self._attr(base, node.attr)
        if isinstance(node, ast.Subscript):
            base = self._expr(node.value, env)
            idx = self._slice(node.slice, env)
            return self._subscript(base, idx)
        if isinstance(node, ast.ListComp):
            return self._comp(node, env)
        if isinstance(node, ast.GeneratorExp):
            return self._comp(node, env)
        if isinstance(node, ast.JoinedStr):
            parts = []
            for v in node.values:
                if isinstance(v, ast.Constant):
                    parts.append(str(v.value))
                else:
                    parts.append(str(self._expr(v.value, env)))
            return "".join(parts)
        raise AnalysisError(
            f"unsupported expression {type(node).__name__} at line "
            f"{getattr(node, 'lineno', '?')}")

    def _comp(self, node, env: Frame):
        if len(node.generators) != 1:
            raise AnalysisError("nested comprehensions unsupported")
        gen = node.generators[0]
        it = self._expr(gen.iter, env)
        out = []
        for v in it:
            frame = Frame(env)
            self._assign(gen.target, v, frame)
            if all(self._concrete_cond(c, frame) for c in gen.ifs):
                out.append(self._expr(node.elt, frame))
        return out

    def _slice(self, node, env: Frame):
        if isinstance(node, ast.Slice):
            return slice(
                self._expr(node.lower, env) if node.lower else None,
                self._expr(node.upper, env) if node.upper else None,
                self._expr(node.step, env) if node.step else None)
        if isinstance(node, ast.Tuple):
            return tuple(self._slice(e, env) for e in node.elts)
        return self._expr(node, env)

    def _subscript(self, base, idx):
        if isinstance(base, Table):
            return base[idx]
        if isinstance(base, (tuple, list, str, dict, range)):
            return base[idx]
        if isinstance(base, FakeArray):
            raise AnalysisError("value indexing of a FakeArray (only "
                                ".shape / .dtype are abstracted)")
        raise AnalysisError(f"cannot subscript {base!r}")

    def _attr(self, base, attr):
        if isinstance(base, NS):
            return getattr(base, attr)
        if isinstance(base, (FakeArray, Table, ScratchV, ShapeDtypeV,
                             BlockSpecV, GridSpecV)):
            if attr in ("shape", "dtype", "ndim", "size", "astype",
                        "reshape", "transpose", "block_shape", "index_map",
                        "memory_space", "grid", "in_specs", "out_specs",
                        "scratch_shapes", "num_scalar_prefetch", "name",
                        "lo", "hi"):
                return getattr(base, attr)
            raise AnalysisError(f"unsupported attribute .{attr} on "
                                f"{type(base).__name__}")
        if isinstance(base, list) and attr in ("append", "extend", "pop"):
            return getattr(base, attr)
        if isinstance(base, str):
            # dtype-token attribute chains like jnp.float32 -> "jnp.float32"
            return f"{base}.{attr}"
        raise AnalysisError(f"unsupported attribute .{attr} on {base!r}")

    def _binary(self, op, a, b):
        symbolic = isinstance(a, (Sym, SymBool)) or isinstance(
            b, (Sym, SymBool))
        if isinstance(op, ast.Add):
            return a + b
        if isinstance(op, ast.Sub):
            return a - b
        if isinstance(op, ast.Mult):
            return a * b
        if isinstance(op, ast.FloorDiv):
            return a // b
        if isinstance(op, ast.Mod):
            return a % b
        if isinstance(op, ast.Div):
            if symbolic:
                raise AnalysisError("true division on symbolic values")
            return a / b
        if isinstance(op, ast.Pow):
            if symbolic:
                raise AnalysisError("pow on symbolic values")
            return a ** b
        raise AnalysisError(f"unsupported operator {type(op).__name__}")

    def _compare(self, op, a, b):
        if isinstance(op, ast.Is):
            return a is b
        if isinstance(op, ast.IsNot):
            return a is not b
        if isinstance(op, ast.In):
            return a in b
        if isinstance(op, ast.NotIn):
            return a not in b
        symbolic = isinstance(a, Sym) or isinstance(b, Sym)
        if symbolic:
            a, b = sym(a), sym(b)
            tbl = {ast.Lt: "lt", ast.LtE: "le", ast.Gt: "gt", ast.GtE: "ge",
                   ast.Eq: "eq", ast.NotEq: "ne"}
            return SymBool(tbl[type(op)], a, b)
        if isinstance(op, ast.Lt):
            return a < b
        if isinstance(op, ast.LtE):
            return a <= b
        if isinstance(op, ast.Gt):
            return a > b
        if isinstance(op, ast.GtE):
            return a >= b
        if isinstance(op, ast.Eq):
            return a == b
        if isinstance(op, ast.NotEq):
            return a != b
        raise AnalysisError(f"unsupported compare {type(op).__name__}")


def interpret_file(path_or_src, path: str = "<string>"):
    """Parse + abstractly execute a module; -> (Interp, module Frame)."""
    src = path_or_src
    tree = ast.parse(src, filename=path)
    interp = Interp()
    env = interp.run_module(tree)
    return interp, env
