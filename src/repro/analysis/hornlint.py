"""hornlint CLI.

    python -m repro.analysis.hornlint [paths...] [options]

Exit codes: 0 = clean (or only baselined findings), 1 = new findings,
2 = bad invocation.  Default paths are ``src`` and ``benchmarks``;
default baseline is the committed ``src/repro/analysis/baseline.json``
(``--baseline none`` disables the diff — every finding fails, the mode
CI uses on seeded-violation fixtures).  ``--github`` emits one
``::error file=...`` workflow annotation per new finding so they land
inline on the PR diff.

    # full run against the committed baseline
    python -m repro.analysis.hornlint src

    # accept current findings as the new baseline
    python -m repro.analysis.hornlint src --write-baseline

    # single rule family, raw findings
    python -m repro.analysis.hornlint src --rules HL301,HL302 --baseline none
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import core

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="hornlint",
        description="static analysis for the serving stack's jit, sync, "
                    "Pallas, and pool-lifetime contracts")
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                    help="files or directories to lint "
                         "(default: src benchmarks)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline JSON to diff against, or 'none'")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to enable (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--github", action="store_true",
                    help="emit GitHub Actions ::error annotations for "
                         "new findings (combinable with --json)")
    ap.add_argument("--root", default=".",
                    help="path findings are reported relative to")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule, desc in core.all_rules().items():
            print(f"{rule}  {desc}")
        return 0
    rules = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = rules - set(core.all_rules())
        if unknown:
            print(f"unknown rules: {sorted(unknown)}", file=sys.stderr)
            return 2
    paths = [Path(p) for p in args.paths]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"no such path: {missing}", file=sys.stderr)
        return 2
    findings = core.lint_paths(paths, root=Path(args.root), rules=rules)

    if args.write_baseline:
        base_path = Path(args.baseline) if args.baseline != "none" \
            else DEFAULT_BASELINE
        core.write_baseline(findings, base_path)
        print(f"wrote {len(findings)} finding(s) to {base_path}")
        return 0

    baseline = {}
    if args.baseline != "none":
        base_path = Path(args.baseline)
        if base_path.exists():
            baseline = core.load_baseline(base_path)
        elif args.baseline != str(DEFAULT_BASELINE):
            print(f"baseline not found: {base_path}", file=sys.stderr)
            return 2
    new, fixed = core.diff_baseline(findings, baseline)

    if args.github:
        for f in new:
            # workflow-command message field: newlines/percents must be
            # URL-encoded or the annotation is truncated
            msg = (f.message.replace("%", "%25").replace("\n", "%0A")
                   .replace("\r", ""))
            print(f"::error file={f.path},line={f.line},"
                  f"col={f.col + 1},title=hornlint {f.rule}::{msg}")

    if args.as_json:
        print(json.dumps({
            "findings": [vars(f) | {"fingerprint": f.fingerprint}
                         for f in findings],
            "new": [f.fingerprint for f in new],
            "fixed": [e["fingerprint"] for e in fixed],
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        n_base = len(findings) - len(new)
        if n_base:
            print(f"hornlint: {n_base} baselined finding(s) not shown")
        if fixed:
            print(f"hornlint: {len(fixed)} baselined finding(s) no longer "
                  f"fire — regenerate with --write-baseline to tighten")
        print(f"hornlint: {len(new)} new finding(s) "
              f"across {len(core.iter_py_files(paths))} file(s)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
