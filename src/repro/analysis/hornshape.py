"""hornshape driver: prove BlockSpec/grid safety for the repo's kernels.

``python -m repro.analysis.hornshape`` (no args) abstractly executes every
registered kernel wrapper under ``symbolic.Interp`` at several concrete
geometry instantiations (ragged tails, multi-page steps, GQA grouping,
quantized sidecars, fused verify windows), captures each ``pallas_call``,
and runs :mod:`repro.analysis.blockspec_verify` over it.  Exit 0 when every
obligation is proved, 1 with findings (each carrying a counterexample grid
point), 2 on driver error.

What is *proved* vs *linted*: for a given shape instantiation the grid-
index quantifier is discharged symbolically (or by exhaustive enumeration
— both sound); the shape-parameter quantifier is discharged by the
representative instantiations below, chosen to hit every branch of the
wrappers (ragged / divisible, pps 1 / >1, quantized on / off, window on /
off).  That is strictly stronger than the HL3xx syntactic checks but
weaker than a proof over all shapes.

Explicit file arguments may instead carry their own geometry declarations:
a module-level literal

    HORNSHAPE = {"entries": [
        {"fn": "my_kernel",
         "args": [{"array": [8, 16]}, {"table": "bt", "shape": [4],
                   "range": [0, 7]}, 4],
         "kwargs": {"block": 4},
         "null_page": ["bt", 0]},            # optional
    ]}

(the seeded-violation fixtures under ``tests/hornlint_fixtures/`` use
this).  ``serve.py --sanitize`` reuses :func:`crosscheck_paged_geometry`
to re-verify the *serving engine's actual* paged-attention geometry at
runtime and cross-check the symbolic verdicts against brute-force
enumeration for one tick.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.blockspec_verify import (GeometryError, Report,
                                             brute_force,
                                             geometry_from_capture, verify)
from repro.analysis.symbolic import (AnalysisError, FakeArray, Interp,
                                     Table, interpret_file)


# --------------------------------------------------------------------------
# built-in geometry registry for the four committed kernel packages
# --------------------------------------------------------------------------
def _paged_entries() -> List[dict]:
    def decode(B, H, KH, D, psize, P, maxp, **kw):
        args = [FakeArray((B, H, D)),
                FakeArray((P, psize, KH, D), kw.pop("kv_dtype", "bfloat16")),
                FakeArray((P, psize, KH, D), "bfloat16"),
                Table("block_tables", (B, maxp), 0, P - 1),
                Table("lengths", (B,), 0, maxp * psize)]
        if kw.pop("quantized", False):
            args[1] = FakeArray((P, psize, KH, D), "int8")
            args[2] = FakeArray((P, psize, KH, D), "int8")
            kw["k_scale"] = FakeArray((P, KH))
            kw["v_scale"] = FakeArray((P, KH))
        return args, {"scale": 0.5, **kw}

    def chunk(B, C, H, KH, D, psize, P, maxp, S_w=0, **kw):
        args = [FakeArray((B, C, H, D)),
                FakeArray((P, psize, KH, D), "bfloat16"),
                FakeArray((P, psize, KH, D), "bfloat16"),
                Table("block_tables", (B, maxp), 0, P - 1),
                Table("starts", (B,), 0, maxp * psize),
                Table("chunk_lens", (B,), 0, C)]
        if S_w:
            kw["logit_index"] = Table("logit_index", (B, S_w), 0, C - 1)
        return args, {"scale": 0.5, **kw}

    return [
        # ragged page tail (maxp % pps != 0) + multi-page grid steps
        {"fn": "paged_attention", "label": "decode/pps2-ragged",
         "build": lambda: decode(2, 4, 2, 8, 4, 6, 5, pages_per_step=2)},
        {"fn": "paged_attention", "label": "decode/pps1",
         "build": lambda: decode(2, 4, 2, 8, 4, 5, 3)},
        {"fn": "paged_attention", "label": "decode/int8",
         "build": lambda: decode(2, 4, 2, 8, 4, 6, 5, quantized=True,
                                 pages_per_step=2)},
        {"fn": "paged_chunk_attention", "label": "chunk/pps2-ragged",
         "build": lambda: chunk(2, 3, 4, 2, 8, 4, 6, 5, pages_per_step=2)},
        {"fn": "paged_chunk_attention", "label": "chunk/verify-window",
         "build": lambda: chunk(2, 4, 4, 2, 8, 4, 6, 7, S_w=2,
                                pages_per_step=3)},
    ]


def _flash_entries() -> List[dict]:
    def build(B, H, KH, Sq, Skv, D, **kw):
        a = [FakeArray((B, H, Sq, D)), FakeArray((B, KH, Skv, D)),
             FakeArray((B, KH, Skv, D))]
        return a, {"scale": 1.0, **kw}

    return [
        {"fn": "flash_attention", "label": "flash/causal-gqa",
         "build": lambda: build(2, 4, 2, 24, 40, 8, block_q=8, block_k=16)},
        # non-divisible block sizes: the wrapper's bq //= 2 loop must yield
        # an exactly-covering grid
        {"fn": "flash_attention", "label": "flash/window-ragged-blocks",
         "build": lambda: build(2, 2, 2, 24, 40, 8, block_q=16, block_k=16,
                                causal=False, window=8)},
    ]


def _dropout_entries() -> List[dict]:
    def build(G, M, K, N, **kw):
        return ([FakeArray((G, M, K)), FakeArray((K, N)),
                 FakeArray((G, N // kw.get("block_n", 128)))], kw)

    return [
        {"fn": "dropout_matmul", "label": "dropout/4d-grid",
         "build": lambda: build(3, 16, 32, 64, block_m=8, block_n=32,
                                block_k=16)},
    ]


def _ssd_entries() -> List[dict]:
    def build(B, S, H, P, N, **kw):
        return ([FakeArray((B, S, H, P)), FakeArray((B, S, H)),
                 FakeArray((H,)), FakeArray((B, S, N)),
                 FakeArray((B, S, N))], kw)

    return [
        {"fn": "ssd_chunk_scan", "label": "ssd/chunked",
         "build": lambda: build(2, 24, 3, 4, 8, chunk=8)},
        {"fn": "ssd_chunk_scan", "label": "ssd/chunk-shrunk",
         "build": lambda: build(2, 24, 3, 4, 8, chunk=7)},
    ]


KERNEL_SPECS: Dict[str, List[dict]] = {
    "src/repro/kernels/paged_attention/kernel.py": _paged_entries(),
    "src/repro/kernels/flash_attention/kernel.py": _flash_entries(),
    "src/repro/kernels/dropout_matmul/kernel.py": _dropout_entries(),
    "src/repro/kernels/ssd/kernel.py": _ssd_entries(),
}

# kernels whose block-table gathers must honor the NULL_PAGE contract
_NULL_PAGE_TABLE = {"paged_attention": "block_tables",
                    "paged_chunk_attention": "block_tables"}


# --------------------------------------------------------------------------
# running entries against a file
# --------------------------------------------------------------------------
def _null_page_contract(env, fn: str,
                        override=None) -> Optional[Tuple[str, int]]:
    if override is not None:
        return tuple(override)
    table = _NULL_PAGE_TABLE.get(fn)
    if table is None:
        return None
    null_page = env.get("NULL_PAGE") if env.has("NULL_PAGE") else 0
    return (table, null_page)


def run_entry(path: str, src: str, entry: dict) -> List[Report]:
    """Interpret ``src``, call ``entry['fn']``, verify every capture."""
    interp, env = interpret_file(src, path)
    fn = entry["fn"]
    if not env.has(fn):
        raise GeometryError(f"{path}: no function {fn!r} at module level")
    if "build" in entry:
        args, kwargs = entry["build"]()
    else:
        args, kwargs = _decode_literal_args(entry)
    interp.call(env.get(fn), tuple(args), kwargs)
    if not interp.captures:
        raise GeometryError(f"{path}: {fn} made no pallas_call")
    contract = _null_page_contract(env, fn, entry.get("null_page"))
    label = entry.get("label", fn)
    reports = []
    for i, cap in enumerate(interp.captures):
        name = label if len(interp.captures) == 1 else f"{label}#{i}"
        geom = geometry_from_capture(cap, name, path, null_page=contract)
        reports.append(verify(geom))
    return reports


def _decode_literal_args(entry: dict):
    def dec(spec):
        if isinstance(spec, dict):
            if "array" in spec:
                return FakeArray(tuple(spec["array"]),
                                 spec.get("dtype", "float32"))
            if "table" in spec:
                lo, hi = spec.get("range", (0, 0))
                return Table(spec["table"], tuple(spec["shape"]), lo, hi)
            raise GeometryError(f"bad HORNSHAPE arg spec {spec!r}")
        return spec

    args = [dec(a) for a in entry.get("args", [])]
    kwargs = {k: dec(v) for k, v in entry.get("kwargs", {}).items()}
    return args, kwargs


def _hornshape_decl(src: str) -> Optional[dict]:
    """The module-level ``HORNSHAPE = {literal}`` declaration, if any."""
    try:
        tree = ast.parse(src)
    except SyntaxError:
        return None
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id == "HORNSHAPE":
            return ast.literal_eval(stmt.value)
    return None


def entries_for(path: Path, src: str) -> Optional[List[dict]]:
    decl = _hornshape_decl(src)
    if decl is not None:
        return list(decl.get("entries", []))
    posix = path.as_posix()
    for suffix, entries in KERNEL_SPECS.items():
        if posix.endswith(suffix) or posix.endswith(
                suffix.split("src/repro/")[-1]):
            return entries
    return None


def check_file(path: Path) -> List[Report]:
    src = path.read_text()
    entries = entries_for(path, src)
    if entries is None:
        raise GeometryError(
            f"{path}: no HORNSHAPE declaration and not a registered kernel")
    reports: List[Report] = []
    for entry in entries:
        reports.extend(run_entry(str(path), src, entry))
    return reports


def check_kernels(root: Path = Path(".")) -> List[Tuple[str, Report]]:
    """Verify every registered kernel file under ``root``."""
    out: List[Tuple[str, Report]] = []
    for rel in KERNEL_SPECS:
        p = root / rel
        for rep in check_file(p):
            out.append((rel, rep))
    return out


# --------------------------------------------------------------------------
# runtime twin: cross-check symbolic verdicts at the engine's geometry
# --------------------------------------------------------------------------
def crosscheck_paged_geometry(*, batch: int, kv_heads: int, head_dim: int,
                              page_size: int, num_pages: int,
                              max_pages: int, pages_per_step: int = 1,
                              quantized: bool = False) -> List[str]:
    """Verify paged attention at a *concrete serving* geometry and compare
    the symbolic verdicts against brute-force enumeration.  Returns alert
    strings (empty == proved and consistent) for ``serve.py --sanitize``."""
    rel = "src/repro/kernels/paged_attention/kernel.py"
    path = _find_kernel_source(rel)
    if path is None:
        return [f"hornshape: cannot locate {rel}"]
    H = kv_heads * max(1, 4 // max(kv_heads, 1))  # any multiple of KH works
    entry = {
        "fn": "paged_attention", "label": "runtime-geometry",
        "build": lambda: (
            [FakeArray((batch, H, head_dim)),
             FakeArray((num_pages, page_size, kv_heads, head_dim),
                       "int8" if quantized else "bfloat16"),
             FakeArray((num_pages, page_size, kv_heads, head_dim),
                       "int8" if quantized else "bfloat16"),
             Table("block_tables", (batch, max_pages), 0, num_pages - 1),
             Table("lengths", (batch,), 0, max_pages * page_size)],
            dict(scale=1.0, pages_per_step=pages_per_step,
                 **({"k_scale": FakeArray((num_pages, kv_heads)),
                     "v_scale": FakeArray((num_pages, kv_heads))}
                    if quantized else {}))),
    }
    alerts: List[str] = []
    try:
        reports = run_entry(str(path), path.read_text(), entry)
    except (GeometryError, AnalysisError) as e:
        return [f"hornshape: {e}"]
    for rep in reports:
        for f in rep.findings:
            alerts.append(f"hornshape: {f.rule} {f.message}")
        try:
            bf = brute_force(rep.geometry)
        except GeometryError:
            continue
        for k, v in bf.items():
            sv = rep.verdicts.get(k)
            if sv is not None and sv != v:
                alerts.append(
                    f"hornshape-divergence: {k} symbolic={sv!r} "
                    f"brute-force={v!r} at the engine geometry")
    return alerts


def _find_kernel_source(rel: str) -> Optional[Path]:
    for base in (Path.cwd(), Path.cwd().parent,
                 Path(__file__).resolve().parents[3]):
        p = base / rel
        if p.exists():
            return p
        q = base / rel.split("src/")[-1]
        if q.exists():
            return q
    return None


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="hornshape",
        description="symbolic BlockSpec/grid verification for Pallas calls")
    ap.add_argument("paths", nargs="*",
                    help="kernel files (default: the built-in registry)")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)

    results: List[Tuple[str, Report]] = []
    try:
        if args.paths:
            for p in args.paths:
                for rep in check_file(Path(p)):
                    results.append((p, rep))
        else:
            results = check_kernels()
    except (GeometryError, AnalysisError, OSError) as e:
        print(f"hornshape: error: {e}", file=sys.stderr)
        return 2

    n_findings = sum(len(r.findings) for _, r in results)
    if args.as_json:
        doc = {
            "results": [
                {"path": p, "geometry": r.geometry.name,
                 "grid": list(r.geometry.grid),
                 "obligations": len(r.verdicts),
                 "symbolic": r.proved_symbolically(),
                 "findings": [
                     {"rule": f.rule, "path": f.path, "line": f.line,
                      "message": f.message} for f in r.findings]}
                for p, r in results],
            "ok": n_findings == 0,
        }
        print(json.dumps(doc, indent=2))
    else:
        for _, rep in results:
            print("\n".join(rep.render()))
        total = sum(len(r.verdicts) for _, r in results)
        sym = sum(r.proved_symbolically() for _, r in results)
        print(f"hornshape: {len(results)} geometries, {total} obligations "
              f"({sym} symbolic), {n_findings} findings")
    return 1 if n_findings else 0


if __name__ == "__main__":
    sys.exit(main())
