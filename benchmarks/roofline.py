"""Roofline table renderer: reads the dry-run JSONs and emits the
EXPERIMENTS.md §Roofline table + one-line CSV rows for run.py."""
from __future__ import annotations

import glob
import json
import os


def load_rows(paths=None):
    paths = paths or (glob.glob("dryrun_*.json"))
    rows = []
    seen = set()
    for p in sorted(paths):
        try:
            data = json.load(open(p))
        except (OSError, json.JSONDecodeError):
            continue
        for r in (data if isinstance(data, list) else [data]):
            key = (r.get("arch"), r.get("shape"), r.get("mesh"))
            if r.get("status") == "ok" and key not in seen:
                seen.add(key)
                rows.append(r)
    return rows


def fmt_table(rows, mesh="16x16"):
    out = ["| arch | shape | t_compute | t_memory | t_coll | dominant | "
           "model/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f}s "
            f"| {r['t_memory_s']:.3f}s | {r['t_collective_s']:.3f}s "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def fmt_kernel_table(kb):
    """Render BENCH_serving.json's ``kernel_bench`` phase (paged-attention
    variant micro-bench: pages_per_step x {f32, int8}) as the same style of
    markdown table — tok/s and achieved KV bytes/s per kernel variant."""
    out = ["| variant | pages/step | wall_us | tok/s | KV GB/s |",
           "|---|---|---|---|---|"]
    for dtype in ("f32", "int8"):
        for pps, row in sorted(kb.get(dtype, {}).items()):
            out.append(f"| {dtype} | {pps[3:]} | {row['wall_us']} "
                       f"| {row['tok_s']} | {row['kv_gb_s']} |")
    return "\n".join(out)


def run():
    rows = load_rows()
    csv = []
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        csv.append((f"roofline_{r['arch']}_{r['shape']}",
                    r["bound_time_s"] * 1e6 if "bound_time_s" in r else
                    max(r["t_compute_s"], r["t_memory_s"],
                        r["t_collective_s"]) * 1e6,
                    f"dominant={r['dominant']} "
                    f"frac={r['roofline_fraction']:.3f}"))
    return csv, {"n_cells": len(rows)}


if __name__ == "__main__":
    rows = load_rows()
    print(fmt_table(rows))
    print()
    print(fmt_table(rows, mesh="2x16x16"))
    if os.path.exists("BENCH_serving.json"):
        kb = json.load(open("BENCH_serving.json")).get("kernel_bench")
        if kb:
            print()
            print(fmt_kernel_table(kb))
