"""Serving benchmark: continuous-batching engine under a fixed synthetic
load; emits ``BENCH_serving.json`` so the perf trajectory is recorded per PR.

    PYTHONPATH=src python benchmarks/serving_bench.py [--arch qwen3-1.7b]
        [--requests 32] [--out BENCH_serving.json]

Metrics (virtual arrival clock at --rate req/s, wall-clock service times):
  decode_tok_s   generated tokens / wall time of the measured phase
  tok_per_step   mean decode-batch occupancy (continuous-batching win)
  ttft_p50/p99   arrival -> first token (s)
  lat_p50/p99    arrival -> completion (s)
  peak_util      page-pool peak utilization

A warmup pass (same buckets) runs first so compile time doesn't pollute the
steady-state numbers.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run(arch: str = "qwen3-1.7b", requests: int = 32, rate: float = 16.0,
        slots: int = 8, pages: int = 512, page_size: int = 16,
        max_prompt: int = 64, gen: int = 16, seed: int = 0):
    import jax
    from repro.configs.base import get_model_config, reduced
    from repro.launch.serve import make_requests
    from repro.models import api
    from repro.serving import Engine, EngineConfig

    cfg = reduced(get_model_config(arch))
    params = api.model_init(jax.random.key(seed), cfg)
    ecfg = EngineConfig(
        num_slots=slots, num_pages=pages, page_size=page_size,
        max_prompt_len=-(-max_prompt // page_size) * page_size,
        max_new_tokens=gen, seed=seed, policy="on_demand")
    rng = np.random.default_rng(seed)

    def load(n):
        return make_requests(n, cfg.vocab_size, rng, rate=rate,
                             max_prompt=max_prompt, gen=gen)

    def drive(engine, reqs):
        """Arrivals on the same wall clock as serve.py, except that when the
        engine fully drains the next future arrival is pulled forward —
        measures service, not idle waiting."""
        t0 = time.monotonic()
        pending = list(reqs)
        while pending or engine.sched.has_work():
            now = time.monotonic() - t0
            while pending and pending[0][0] <= now:
                at, prompt, g = pending.pop(0)
                engine.submit(prompt, g, arrival_time=at)
            if not engine.sched.has_work() and pending:
                at, prompt, g = pending.pop(0)
                engine.submit(prompt, g, arrival_time=min(at, now))
            engine.step(time.monotonic() - t0,
                        tick_clock=lambda: time.monotonic() - t0)
        return time.monotonic() - t0

    # warmup: populate the prefill-bucket + decode compile caches
    warm = Engine(cfg, params, ecfg)
    drive(warm, load(max(4, slots // 2)))

    engine = Engine(cfg, params, ecfg)
    wall = drive(engine, load(requests))
    done = engine.sched.finished
    ttft = np.asarray([r.t_first_token - r.arrival_time for r in done])
    lat = np.asarray([r.t_done - r.arrival_time for r in done])
    total_new = sum(len(r.out_tokens) for r in done)
    return {
        "arch": arch, "requests": requests, "slots": slots,
        "pages": pages, "page_size": page_size,
        "wall_s": round(wall, 3),
        "decode_tok_s": round(total_new / max(wall, 1e-9), 2),
        "tok_per_step": round(engine.generated_tokens
                              / max(engine.steps, 1), 2),
        "ttft_p50_s": round(float(np.percentile(ttft, 50)), 4),
        "ttft_p99_s": round(float(np.percentile(ttft, 99)), 4),
        "lat_p50_s": round(float(np.percentile(lat, 50)), 4),
        "lat_p99_s": round(float(np.percentile(lat, 99)), 4),
        "peak_util": round(engine.peak_utilization, 4),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    res = run(arch=args.arch, requests=args.requests, rate=args.rate,
              slots=args.slots)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
        f.write("\n")
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
