"""Serving benchmark: continuous-batching engine under a fixed synthetic
load; emits ``BENCH_serving.json`` so the perf trajectory is recorded per PR.

    PYTHONPATH=src python benchmarks/serving_bench.py [--arch qwen3-1.7b]
        [--requests 32] [--long-frac 0.1] [--out BENCH_serving.json]

Nine phases:
  "default"        the log-uniform prompt mix (comparable across PRs)
  "long_mix"       the adversarial mix: ``--long-frac`` of prompts pinned
                   at ``max_prompt`` exactly.  Before chunked prefill,
                   every such admission stalled the whole decode batch for
                   a serial full-prompt prefill; now a tick is bounded by
                   the token budget, so ``stall_max_s`` should sit near
                   ``tick_p50_s`` instead of scaling with prompt length.
  "squeeze"        a deliberately undersized pool (13 x 4-token pages, 4
                   slots) under ``on_demand`` — the load that used to exit
                   2 with EngineOOM; records the throughput cost of
                   preempt + chunked re-prefill (``preemptions`` must be
                   > 0 here or the phase is not squeezing).
  "multi_submodel" the same default load served by a 4-circuit ModelBank
                   (least-loaded routing, 25% of requests fanned as
                   mean-logit ensembles): per-submodel tok/s and pool
                   pressure, TTFT, and the co-batch ratio — the fraction
                   of ticks whose ONE jitted call carried >= 2 distinct
                   sub-models (must be > 0 or nothing is co-batching).
                   An ensemble group counts ONCE in ttft/lat percentiles
                   and in ``delivered_tok_s`` (one user-visible stream);
                   ``decode_tok_s`` keeps counting per-member device
                   tokens, so the two diverge exactly by the ensemble
                   fan-out.
  "prefix_cache"   the same load served cold (--no-prefix-cache) and warm:
                   "shared_prompt_*" pins 3/4 of every prompt to one
                   system prefix (the millions-of-users mix — warm must
                   show a high ``prefix_hit_rate``, big
                   ``prefill_tok_saved``, and strictly lower TTFT p50);
                   "ensemble_*" fans every request across all circuits
                   (warm prefill_tok ~ 1/G of cold: the leader encodes
                   the shared context once, members fork its pages and
                   copy-on-write only their decode tails).
  "speculative"    a decode-heavy mix (short prompts, long generations)
                   served plain and with ``--speculate-k`` draft tokens
                   per decode tick: ``accept_rate`` (drafts surviving
                   verification), ``accepted_tok_per_tick`` (committed
                   tokens per speculating slot-tick; plain decode's
                   ceiling is 1.0), and decode tok/s against the
                   non-speculative baseline on the SAME mix.  Both runs
                   use the replay warmup (the measured load driven once,
                   compile-free clock) and no prefix cache, so the delta
                   is speculation alone.
  "kernel_bench"   roofline-style micro-bench of the paged chunk-attention
                   kernel variants: pages_per_step in {1, 2, 4} x
                   {f32, int8} pools, reporting per-variant wall time,
                   decode tok/s and achieved KV bytes/s (interpret mode on
                   CPU — a scheduling proxy; the compiled kernel on TPU).
  "int8"           the quantized paged-KV phase: effective capacity ratio
                   of int8 pages + f32 scale sidecars vs bf16 at equal
                   HBM, the squeeze load rerun with the page count that
                   budget affords under int8 (preemptions must drop), and
                   the greedy-decode divergence bound vs an f32 engine.
  "observability"  the decode-heavy closed-loop mix served with telemetry
                   fully off (no lifecycle tracer, no timeline) and fully
                   on (tracer + per-tick Perfetto timeline, unbounded
                   retention): ``overhead_frac`` is the decode tok/s cost
                   of full tracing, CI-gated at <= 3% — instrumentation
                   must stay on the host side of the jitted step.

Metrics (virtual arrival clock at --rate req/s, wall-clock service times):
  decode_tok_s   generated tokens / wall time of the measured phase
  tok_per_tick   mean decode-batch occupancy (continuous-batching win)
  ttft_p50/p99   arrival -> first token (s)
  lat_p50/p99    arrival -> completion (s)
  tick_p50_s     median unified-tick duration
  stall_p99_s /  per-tick wall time observed while >=1 already-running
  stall_max_s    request was decoding — the decode-latency spike an
                 admission injects (the number chunked prefill bounds)
  peak_util      page-pool peak utilization
  preemptions    pool-pressure evictions (on_demand policy)

A warmup pass (same chunk-width buckets) runs first so compile time doesn't
pollute the steady-state numbers.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def run(arch: str = "qwen3-1.7b", requests: int = 32, rate: float = 16.0,
        slots: int = 8, pages: int = 512, page_size: int = 16,
        max_prompt: int = 64, gen: int = 16, budget: int = 64,
        long_frac: float = 0.0, stream: str = "poisson", seed: int = 0,
        submodels: int = 0, ensemble_frac: float = 0.0,
        prefix_cache: bool = True, shared_prefix: int = 0,
        speculate: int = 0, draft_keep: float = 0.875,
        warm_with_load: bool = False, observability: str = "default",
        keep_ticks: bool = False, kv_dtype: str = "bfloat16",
        pages_per_step: int = 1, _engine_cache={}):
    import jax
    from repro.configs.base import HornConfig, get_model_config, reduced
    from repro.launch.serve import build_draft, make_requests
    from repro.models import api
    from repro.serving import Engine, EngineConfig, ModelBank, Router, \
        Telemetry
    from repro.serving.observability import percentile_or_none

    cfg = reduced(get_model_config(arch))
    ecfg = EngineConfig(
        num_slots=slots, num_pages=pages, page_size=page_size,
        max_prompt_len=-(-max_prompt // page_size) * page_size,
        max_new_tokens=gen, token_budget=max(budget, slots), seed=seed,
        policy="on_demand", prefix_cache=prefix_cache,
        speculate_k=speculate, kv_dtype=kv_dtype,
        pages_per_step=pages_per_step)
    key = (arch, seed)
    if key not in _engine_cache:          # share params across phases
        _engine_cache.clear()
        _engine_cache[key] = api.model_init(jax.random.key(seed), cfg)
    params = _engine_cache[key]
    rng = np.random.default_rng(seed)
    bank = router = None
    if submodels:
        # slots >= submodels for ensembles is validated by Engine.submit
        bank = ModelBank(cfg, HornConfig(enabled=True, keep_hidden=0.5,
                                         keep_input=1.0, block_size=16),
                         submodels, seed=seed)
        router = Router(submodels)        # least-loaded
    draft = build_draft(cfg, params, bank, speculate=speculate,
                        draft_circuit=0, draft_keep=draft_keep,
                        mask_block=16, seed=seed)

    def load(n):
        return make_requests(n, cfg.vocab_size, rng, stream=stream,
                             rate=rate, max_prompt=max_prompt, gen=gen,
                             long_frac=long_frac,
                             shared_prefix=shared_prefix)

    def drive(engine, reqs):
        """Arrivals on the same wall clock as serve.py, except that when the
        engine fully drains the next future arrival is pulled forward —
        measures service, not idle waiting.  Returns (wall, ticks, stalls):
        per-tick durations, and the subset observed while at least one
        already-running request was decoding (the stall an admission
        injects into in-flight requests)."""
        t0 = time.monotonic()
        pending = list(reqs)
        ticks, stalls = [], []

        def _submit(at, prompt, g):
            ens = "mean_logit" if bank is not None \
                and rng.uniform() < ensemble_frac else None
            engine.submit(prompt, g, arrival_time=at, ensemble=ens)
            n_ensembles[0] += ens is not None

        while pending or engine.sched.has_work():
            now = time.monotonic() - t0
            while pending and pending[0][0] <= now:
                at, prompt, g = pending.pop(0)
                _submit(at, prompt, g)
            if not engine.sched.has_work() and pending:
                at, prompt, g = pending.pop(0)
                _submit(min(at, now), prompt, g)
            decoding = any(not r.in_prefill
                           for r in engine.sched.running.values())
            tt0 = time.monotonic()
            engine.step(time.monotonic() - t0,
                        tick_clock=lambda: time.monotonic() - t0)
            dt = time.monotonic() - tt0
            ticks.append(dt)
            if decoding:
                stalls.append(dt)
        return time.monotonic() - t0, ticks, stalls

    # warmup: compile every power-of-two chunk-width bucket the measured
    # phase can hit, on the SAME engine (each Engine owns a fresh jit cache,
    # so a throwaway warmup engine would not keep compile spikes out of the
    # stall numbers; a random load would miss rare widths).  The final
    # max-width prompt matters when the budget is not a power of two: a
    # 24-token chunk compiles the C=32 cell no pow2-length prompt reaches
    # "off" = no lifecycle tracer, no timeline (the overhead baseline);
    # "full" = tracer + per-tick timeline with unbounded retention (what
    # --trace-out costs); "default" = the engine's stock telemetry
    if observability == "off":
        telemetry = Telemetry(tracer=False)
    elif observability == "full":
        telemetry = Telemetry(timeline=True, trace_maxlen=None)
    else:
        telemetry = None
    engine = Engine(cfg, params, ecfg, bank=bank, router=router,
                    draft=draft, telemetry=telemetry)
    widths, w = [engine.max_chunk], 1
    while w < engine.max_chunk:
        widths.append(w)
        w <<= 1
    # warmup prompts are DISTINCT random streams (separate rng): identical
    # prompts would hit the prefix cache and skip the very chunk widths
    # the sweep exists to compile
    wrng = np.random.default_rng(seed + 10_007)
    for w in sorted(widths):
        engine.submit(wrng.integers(1, cfg.vocab_size, (w,)), 2)
        engine.run()
    if bank is not None and ensemble_frac > 0:
        # the combine path is a SEPARATE jit variant (ensembles=True): warm
        # it at every chunk-width bucket too, by co-batching an ensemble
        # with a bucket-width solo prompt (solo admits first -> its chunk
        # sets the tick's C bucket while the group is in flight)
        for w in sorted(widths):
            engine.submit(wrng.integers(1, cfg.vocab_size, (w,)), 2)
            engine.submit(wrng.integers(1, cfg.vocab_size, (4,)), 2,
                          ensemble="mean_logit")
            engine.run()
    engine.reset_stats()

    n_ensembles = [0]
    reqs = load(requests)
    if warm_with_load:
        # replay warmup: drive the EXACT measured load once first, so
        # every jit cell it hits — including the speculative verify-window
        # and draft catch-up buckets, whose (C, S_v) combinations a width
        # sweep cannot enumerate — is compiled before the clock starts.
        # Run with the prefix cache off, or warmup would seed the cache
        # and the measured run would hit different cells than it compiled.
        assert not prefix_cache, "replay warmup needs prefix_cache=False"
        drive(engine, reqs)
        engine.reset_stats()
        n_ensembles[0] = 0
    cpu0 = time.process_time()
    wall, ticks, stalls = drive(engine, reqs)
    cpu_s = time.process_time() - cpu0
    # an ensemble group delivers ONE token stream through G member slots:
    # latency/TTFT/delivered-throughput count each group once (its leader),
    # while decode_tok_s keeps counting member tokens (device throughput)
    done = engine.finished_streams()
    ttft = np.asarray([r.t_first_token - r.arrival_time for r in done])
    lat = np.asarray([r.t_done - r.arrival_time for r in done])
    total_new = sum(len(r.out_tokens) for r in engine.sched.finished)
    delivered = sum(len(r.out_tokens) for r in done)
    pct = percentile_or_none
    # one telemetry snapshot is the read surface for everything the engine
    # counted; request timestamps stay the ground truth for the exact
    # latency percentiles (the streaming histograms in m["latency"] are
    # the no-retention view of the same samples)
    m = engine.metrics()
    c, d = m["counters"], m["derived"]

    out = {
        "requests": requests, "long_frac": long_frac,
        "wall_s": round(wall, 3),
        "decode_tok_s": round(total_new / max(wall, 1e-9), 2),
        # process CPU time per generated token: the contention-immune
        # instrument the observability overhead gate compares on (wall
        # clock on a shared box jitters far more than a few percent)
        "cpu_us_per_tok": round(cpu_s / max(total_new, 1) * 1e6, 2),
        "tok_per_tick": round(c["generated_tokens"]
                              / max(c["steps"], 1), 2),
        "prefill_tok": c["prefill_tokens"],
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "lat_p50_s": pct(lat, 50), "lat_p99_s": pct(lat, 99),
        "tick_p50_s": pct(ticks, 50),
        "stall_p99_s": pct(stalls, 99), "stall_max_s": pct(stalls, 100),
        "peak_util": round(c["peak_utilization"], 4),
        "preemptions": d["preemptions"],
        "bt_rows_per_tick": round(c["bt_rows_synced"]
                                  / max(c["steps"], 1), 3),
    }
    if prefix_cache:
        hr = d["prefix_hit_rate"]        # None when nothing was eligible
        out.update({
            "prefix_hit_rate": None if hr is None else round(hr, 4),
            "prefill_tok_saved": c["prefill_tok_saved"],
            "cache_evictions": d["cache_evictions"],
            "cow_page_copies": c["cow_page_copies"],
        })
    if speculate:
        out.update({
            "speculate_k": speculate,
            "accept_rate": round(d["accept_rate"], 4),
            "accepted_tok_per_tick": round(d["accepted_tok_per_tick"], 4),
            "spec_drafted": c["spec_drafted"],
            "draft_calls": m["spec"]["draft_calls"],
            "draft_kept_frac": round(engine.spec.draft.kept_frac, 4),
        })
    if bank is not None:
        by_sub = c["tokens_by_submodel"]
        peak_sub = c["peak_util_by_submodel"]
        out.update({
            "submodels": submodels, "ensemble_frac": ensemble_frac,
            "ensemble_groups": n_ensembles[0],
            "delivered_tok_s": round(delivered / max(wall, 1e-9), 2),
            "cobatch_ratio": round(d["cobatch_ratio"], 4),
            "tok_s_by_submodel": {
                str(g): round(by_sub.get(g, 0) / max(wall, 1e-9), 2)
                for g in range(submodels)},
            "peak_util_by_submodel": {
                str(g): round(peak_sub.get(g, 0.0), 4)
                for g in range(submodels)},
        })
    if observability != "default":
        out["observability"] = observability
        if engine.obs.timeline is not None:
            out["timeline_events"] = engine.obs.timeline.num_events
        if engine.obs.tracer is not None:
            out["trace_events"] = engine.obs.tracer.num_events
    if keep_ticks:
        # raw per-tick durations for callers that pool samples across
        # runs (the observability phase); popped before the artifact
        out["_ticks_us"] = [t * 1e6 for t in ticks]
    return out


def observability_phase(args, repeats: int = 3) -> dict:
    """Telemetry fully off vs fully on (lifecycle tracer + per-tick
    Perfetto timeline, unbounded retention) on the same decode-heavy
    closed-loop mix the speculative phase uses — both replay-warmed, so
    ``overhead_frac`` is instrumentation cost alone.

    Estimator: both modes replay the identical batch load (same seed ->
    same tick-by-tick schedule, same tokens per tick), so per-mode tick
    duration is an inverse decode-throughput measure.  Shared-box
    contention only ever makes a tick *slower*, and the per-tick
    telemetry cost is uniform (every tick pays the same hook work), so
    the contention-free cost of a tick is its pooled *p10* across
    interleaved runs — the classic min-timing estimator, applied
    per-tick where hundreds of samples exist instead of per-run where
    three do.  ``overhead_frac`` is the pooled-p10 ratio minus one;
    run-level ``decode_tok_s`` stays in the artifact for reference but
    jitters by tens of percent at sub-second run lengths."""
    from repro.serving.observability import percentile
    kw = dict(arch=args.arch, requests=max(args.requests, 48), slots=4,
              pages=args.pages, page_size=args.page_size, max_prompt=16,
              gen=32, budget=args.budget, stream="batch",
              prefix_cache=False, warm_with_load=True)
    ticks = {"off": [], "full": []}
    runs = {"off": [], "full": []}
    for _ in range(repeats):
        for mode in ("off", "full"):
            r = run(**kw, observability=mode, keep_ticks=True)
            ticks[mode] += r.pop("_ticks_us")
            runs[mode].append(r)
    p10 = {m: percentile(ts, 10) for m, ts in ticks.items()}
    off, full = (max(runs[m], key=lambda r: r["decode_tok_s"])
                 for m in ("off", "full"))
    return {
        "off": off, "full": full,
        "tick_p10_us": {m: round(v, 2) for m, v in p10.items()},
        "tick_samples": {m: len(ts) for m, ts in ticks.items()},
        "overhead_frac": round(p10["full"] / p10["off"] - 1.0, 4),
    }


def kernel_bench_phase(args, reps: int = 3) -> dict:
    """Roofline-style micro-bench of the paged chunk-attention kernel
    (the unified tick's decode workhorse) across its new variants:
    pages_per_step x {f32, int8}.  Each variant reports best-of-``reps``
    wall time per call, decode tok/s (one token per batch row per call),
    and achieved KV bytes/s — the page bytes one layer's grid must move
    from HBM, ``kv_page_bytes`` per live page, so the f32-vs-int8 bytes/s
    gap shows the quantized pool shrinking the memory term, not the
    clock.  On CPU the kernels run in Pallas interpret mode, so absolute
    numbers are a scheduling proxy (per-grid-step overhead dominates:
    pages_per_step > 1 shows up directly as fewer, fatter steps); on TPU
    the same harness times the compiled kernel against the HBM roofline."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_model_config, reduced
    from repro.kernels.paged_attention.kernel import paged_chunk_attention
    from repro.optim.compression import quantize_int8
    from repro.serving.kv_cache import kv_page_bytes

    cfg = reduced(get_model_config(args.arch))
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B, psize, maxp = 4, args.page_size, 16
    interpret = jax.default_backend() == "cpu"
    rng = np.random.default_rng(0)
    P = B * maxp + 1
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, psize, KH, D)), jnp.float32)
    kq, ks = quantize_int8(kp, axis=(1, 3))
    vq, vs = quantize_int8(vp, axis=(1, 3))
    ks, vs = ks[:, 0, :, 0], vs[:, 0, :, 0]
    bt = np.zeros((B, maxp), np.int32)
    for b in range(B):                       # every row at full context
        bt[b] = 1 + b * maxp + np.arange(maxp)
    bt = jnp.asarray(bt)
    starts = jnp.full((B,), maxp * psize - 1, jnp.int32)
    clens = jnp.ones((B,), jnp.int32)

    def bench(pools, scales, dtype_name):
        kw = dict(scale=D ** -0.5, interpret=interpret, **scales)
        out = {}
        for pps in (1, 2, 4):
            fn = lambda: paged_chunk_attention(
                *pools, bt, starts, clens, pages_per_step=pps, **kw)
            jax.block_until_ready(fn())      # compile/trace warmup
            best = min(_timed(fn) for _ in range(reps))
            kv_bytes = B * maxp * kv_page_bytes(psize, KH, D, dtype_name)
            out[f"pps{pps}"] = {
                "wall_us": round(best * 1e6, 1),
                "tok_s": round(B / best, 2),
                "kv_gb_s": round(kv_bytes / best / 1e9, 4),
                # the page-axis extent one (slot, kv-head) pair walks —
                # what pages_per_step actually collapses (the DMA-overlap
                # win this buys is hardware-only; interpret wall time
                # pays python-level plumbing per extra BlockSpec instead)
                "grid_steps": -(-maxp // pps),
            }
        return out

    def _timed(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    return {
        "B": B, "heads": H, "kv_heads": KH, "head_dim": D,
        "page_size": psize, "pages_per_seq": maxp,
        "interpret": interpret,
        "f32": bench((q, kp, vp), {}, "float32"),
        "int8": bench((q, kq, vq),
                      dict(k_scale=ks, v_scale=vs), "int8"),
    }


def int8_phase(args, squeeze_f32: dict) -> dict:
    """The quantized-pool phase: (1) effective capacity — int8 pages +
    scale sidecars vs bf16 at equal HBM bytes (``capacity_ratio`` must
    clear ~2x); (2) the squeeze load rerun under int8 with the page count
    the SAME HBM budget now affords — pool pressure drops, so preemptions
    must come in strictly below the bf16 squeeze; (3) greedy-decode
    divergence vs an f32-pool engine on one load (quantize-on-append
    requantizes whole pages, so exact token match is not expected —
    ``greedy_match_frac`` documents the bound CI gates on)."""
    import jax
    from repro.configs.base import get_model_config, reduced
    from repro.models import api
    from repro.serving import Engine, EngineConfig
    from repro.serving.kv_cache import kv_page_bytes

    cfg = reduced(get_model_config(args.arch))
    KH, D = cfg.num_kv_heads, cfg.head_dim

    def ratio_at(psize):
        return (kv_page_bytes(psize, KH, D, "bfloat16")
                / kv_page_bytes(psize, KH, D, "int8"))

    # headline capacity at the serving default geometry (the ~2x claim
    # needs psize * head_dim to amortize the per-head scale sidecar; the
    # squeeze phase's deliberately tiny 4-token pages sit a bit lower and
    # get their own ratio for the equal-HBM page-count conversion)
    sq_psize, sq_pages = 4, 13               # the squeeze phase's geometry
    pages_int8 = int(sq_pages * ratio_at(sq_psize))
    squeeze_int8 = run(arch=args.arch, requests=args.requests,
                       rate=args.rate, slots=4, pages=pages_int8,
                       page_size=sq_psize, max_prompt=16, gen=12, budget=16,
                       stream="batch", kv_dtype="int8")

    params = api.model_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(7)
    gen = 12
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in rng.integers(3, 13, size=8)]

    def greedy(kv_dtype):
        eng = Engine(cfg, params, EngineConfig(
            num_slots=4, num_pages=64, page_size=4, max_prompt_len=16,
            max_new_tokens=gen, token_budget=24, policy="on_demand",
            kv_dtype=kv_dtype, compute_dtype="float32"))
        for p in prompts:
            eng.submit(p, gen)
        fin = eng.run()
        return [list(r.out_tokens) for r in sorted(fin, key=lambda r: r.id)]

    f32_out, q8_out = greedy("float32"), greedy("int8")
    match = float(np.mean([np.mean([a == b for a, b in zip(x, y)])
                           for x, y in zip(f32_out, q8_out)]))
    return {
        "capacity_ratio": round(ratio_at(args.page_size), 4),
        "squeeze_capacity_ratio": round(ratio_at(sq_psize), 4),
        "squeeze_pages": {"bf16": sq_pages, "int8": pages_int8},
        "squeeze_preemptions": {"bf16": squeeze_f32["preemptions"],
                                "int8": squeeze_int8["preemptions"]},
        "squeeze_int8": squeeze_int8,
        "greedy_match_frac": round(match, 4),
        "greedy_requests": len(prompts), "greedy_gen": gen,
    }


def replay_phase() -> dict:
    """Replay every pinned trace under ``benchmarks/traces/`` and emit
    the baseline block ``benchmarks/regression.py`` gates against:
    per-trace token digest, virtual-clock TTFT/latency p99, pooled-p10
    decode tok/s, accept rate.  Regenerating BENCH_serving.json with
    this script therefore also rebaselines the regression gate."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import regression
    return {name: regression.baseline_entry(res)
            for name, res in regression.replay_phase().items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--pages", type=int, default=512,
                    help="shrink (e.g. 16 4-token pages) to bench the "
                         "preemption path under real pool pressure")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=64)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--long-frac", type=float, default=0.1,
                    help="fraction of long_mix prompts pinned at --max-prompt")
    ap.add_argument("--submodels", type=int, default=4,
                    help="ModelBank size for the multi_submodel phase")
    ap.add_argument("--ensemble-frac", type=float, default=0.25,
                    help="fraction of multi_submodel requests fanned across "
                         "all circuits (mean-logit)")
    ap.add_argument("--speculate-k", type=int, default=4,
                    help="draft tokens per decode tick in the speculative "
                         "phase")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.ensemble_frac > 0 and args.submodels > args.slots:
        raise SystemExit(
            f"ensemble fan-out needs --slots >= --submodels "
            f"({args.slots} < {args.submodels})")
    common = dict(arch=args.arch, requests=args.requests, rate=args.rate,
                  slots=args.slots, pages=args.pages,
                  page_size=args.page_size, max_prompt=args.max_prompt,
                  budget=args.budget)
    res = {
        "arch": args.arch, "slots": args.slots, "budget": args.budget,
        "pages": args.pages, "page_size": args.page_size,
        "max_prompt": args.max_prompt,
        "default": run(**common),
        "long_mix": run(**common, long_frac=args.long_frac),
        "squeeze": run(arch=args.arch, requests=args.requests,
                       rate=args.rate, slots=4, pages=13, page_size=4,
                       max_prompt=16, gen=12, budget=16, stream="batch"),
        "multi_submodel": run(**common, submodels=args.submodels,
                              ensemble_frac=args.ensemble_frac),
        # the prefix-cache phase: identical loads served cold (cache off)
        # and warm (cache on).  shared_prompt pins 3/4 of every prompt to
        # one system prefix — hit rate must be well over 50% and TTFT p50
        # strictly lower than cold; ensemble fans every request across all
        # circuits — warm prefill must approach 1/G of cold
        "prefix_cache": {
            "shared_prompt_cold": run(**common, prefix_cache=False,
                                      shared_prefix=3 * args.max_prompt
                                      // 4),
            "shared_prompt_warm": run(**common, prefix_cache=True,
                                      shared_prefix=3 * args.max_prompt
                                      // 4),
            "ensemble_cold": run(**common, submodels=args.submodels,
                                 ensemble_frac=1.0, prefix_cache=False),
            "ensemble_warm": run(**common, submodels=args.submodels,
                                 ensemble_frac=1.0, prefix_cache=True),
        },
        # speculative decoding vs plain decode on an identical decode-heavy
        # closed-loop mix: short prompts, long generations, few slots (the
        # decode-bound regime where landing K+1 tokens per tick pays)
        "speculative": dict(
            (name, run(arch=args.arch, requests=args.requests,
                       slots=4, pages=args.pages,
                       page_size=args.page_size, max_prompt=16, gen=24,
                       budget=args.budget, stream="batch",
                       prefix_cache=False, warm_with_load=True,
                       speculate=k))
            for name, k in (("baseline", 0), ("speculate",
                                              args.speculate_k))),
        # full tracing vs telemetry-off on the identical decode-heavy
        # closed-loop mix (replay-warmed, compile-free): the decode tok/s
        # cost of observability, CI-gated at <= 3%
        "observability": observability_phase(args),
        # roofline-style kernel micro-bench: pages_per_step x {f32, int8}
        # variants of the paged chunk-attention kernel, tok/s + KV bytes/s
        "kernel_bench": kernel_bench_phase(args),
        # pinned-trace replay baselines (token digests, virtual-clock
        # TTFT/latency, pooled-p10 decode tok/s, accept rate) — the block
        # benchmarks/regression.py gates every CI run against
        "replay": replay_phase(),
    }
    # quantized-pool phase needs the squeeze result for its preemption
    # comparison at equal HBM budget
    res["int8"] = int8_phase(args, res["squeeze"])
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
        f.write("\n")
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
