"""Render FINAL_TABLE.md: baseline (paper-faithful, instrument v1) vs final
(optimized, instrument v2) roofline terms per cell, both meshes."""
import glob
import json


def load(paths):
    rows = {}
    for p in paths:
        try:
            data = json.load(open(p))
        except (OSError, json.JSONDecodeError):
            continue
        for r in (data if isinstance(data, list) else [data]):
            if r.get("status") == "ok":
                rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def decode_mem_frac(r):
    """Decode cells live on the memory roofline: ideal = one cache read per
    token; frac_mem = ideal_mem_time / t_memory."""
    from repro.configs.base import SHAPES, get_model_config
    from repro.launch.analysis import HBM_BW
    try:
        cfg = get_model_config(r["arch"])
        shape = SHAPES[r["shape"]]
    except KeyError:
        return None
    if shape.kind != "decode":
        return None
    B, S = shape.global_batch, shape.seq_len
    cache_bytes = 0.0
    kinds = cfg.layer_kinds()
    for k in kinds:
        if k in ("attn", "local"):
            cache_bytes += 2 * B * S * cfg.num_kv_heads * cfg.head_dim * 2
        else:
            d_in = cfg.ssm_expand * cfg.d_model
            H = d_in // cfg.ssm_head_dim
            cache_bytes += B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
    ideal = cache_bytes / (r["chips"] * HBM_BW)
    return ideal / r["t_memory_s"] if r["t_memory_s"] else None


def main():
    base = load(["dryrun_single_pod.json", "dryrun_multi_pod.json"]
                + glob.glob("dryrun_long500k_*.json"))
    fin = load(["dryrun_final.json"])
    out = ["# Final roofline table — baseline vs optimized",
           "",
           "bound = max(t_compute, t_memory, t_collective); frac = ideal/bound",
           "(compute ideal = MODEL_FLOPS; decode cells additionally report",
           "frac_mem = cache-read-per-token ideal / t_memory — decode's true",
           "roofline is the memory side).  Baseline = paper-faithful system,",
           "instrument v1; see EXPERIMENTS §Roofline.",
           "", ]
    for mesh in ("16x16", "2x16x16"):
        out.append(f"\n## mesh {mesh}\n")
        out.append("| arch | shape | t_cmp | t_mem | t_coll | dominant | "
                   "frac | bound vs baseline |")
        out.append("|---|---|---|---|---|---|---|---|")
        for key in sorted(fin):
            if key[2] != mesh:
                continue
            r = fin[key]
            b = base.get(key)
            bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
            if b:
                bbound = max(b["t_compute_s"], b["t_memory_s"],
                             b["t_collective_s"])
                gain = f"{bbound / bound:.1f}x" if bound else "-"
            else:
                gain = "-"
            mf = decode_mem_frac(r)
            frac = (f"{r['roofline_fraction']:.3f}"
                    if mf is None else f"mem:{mf:.3f}")
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2f} "
                f"| {r['t_memory_s']:.2f} | {r['t_collective_s']:.2f} "
                f"| {r['dominant']} | {frac} | {gain} |")
    # summary stats
    singles = [r for k, r in fin.items() if k[2] == "16x16"]
    if singles:
        import statistics
        fr = []
        for r in singles:
            mf = decode_mem_frac(r)
            fr.append(r["roofline_fraction"] if mf is None else mf)
        out.append(f"\ncells: {len(singles)} | median frac (decode=mem-frac) "
                   f"{statistics.median(fr):.3f} | best {max(fr):.3f}")
    text = "\n".join(out)
    open("FINAL_TABLE.md", "w").write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
