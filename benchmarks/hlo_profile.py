"""HLO profiler for hillclimbing: top collectives / traffic ops by
(bytes x trip-count multiplier), attributed via op_name metadata.

    PYTHONPATH=src python -m benchmarks.hlo_profile --arch llava-next-34b \
        --shape prefill_32k [--multi-pod] [--top 15]
"""
import argparse
import re

from repro.launch import analysis as A

_OPNAME = re.compile(r'op_name="([^"]*)"')


def profile_cell(arch, shape, multi_pod=False, top=15):
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.launch.dryrun import make_run, lower_cell
    from repro.launch.mesh import make_production_mesh
    run = make_run(arch, shape, multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, compiled = lower_cell(run, mesh)
    return profile_hlo(compiled.as_text(), top=top), compiled


def profile_hlo(hlo_text, top=15):
    cm = A.HloCost(hlo_text)
    colls, traffic = [], []
    for c in cm.comps.values():
        me = cm.mult.get(c.name, 0.0)
        mm = cm.mem_mult.get(c.name, 0.0)
        for name, shape_str, opcode, line in c.ops:
            tag = _OPNAME.search(line)
            tag = tag.group(1)[-90:] if tag else "?"
            if any(opcode.startswith(k) for k in A._COLLECTIVES) \
                    and not opcode.endswith("-done") and me:
                b = A.shape_bytes(shape_str)
                if shape_str.startswith("("):
                    b /= 2
                colls.append((b * me, opcode, shape_str[:60], me, tag))
            if opcode not in A._NO_TRAFFIC and not opcode.endswith("-done") \
                    and mm:
                t = A.shape_bytes(shape_str)
                args = line.split("(", 1)[1] if "(" in line else ""
                for ref in re.findall(r"%[\w\.\-]+", args):
                    if ref in c.defs:
                        t += A.shape_bytes(c.defs[ref])
                traffic.append((t * mm, opcode, shape_str[:60], mm, tag))
    out = {"summary": {
        "flops": cm.flops, "bytes": cm.bytes,
        "coll": cm.collectives().bytes_simple,
        "by_tag": cm.by_tag(),
        "coll_by_kind": cm.collectives().by_kind,
    }}
    out["top_collectives"] = sorted(colls, reverse=True)[:top]
    out["top_traffic"] = sorted(traffic, reverse=True)[:top]
    return out


def render(prof):
    s = prof["summary"]
    print(f"per-dev: flops={s['flops']:.3e} bytes={s['bytes']:.3e} "
          f"coll={s['coll']:.3e}")
    print("coll by kind:", {k: f"{v:.2e}" for k, v in s["coll_by_kind"].items()})
    print("by tag:", {k: {kk: f"{vv:.2e}" for kk, vv in v.items()}
                      for k, v in s["by_tag"].items()})
    print("\n-- top collectives (bytes x mult) --")
    for b, op, shape, m, tag in prof["top_collectives"]:
        print(f"  {b:.3e}  {op:18s} x{m:<6.0f} {shape:40s} {tag}")
    print("\n-- top traffic ops --")
    for b, op, shape, m, tag in prof["top_traffic"]:
        print(f"  {b:.3e}  {op:18s} x{m:<6.0f} {shape:40s} {tag}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    prof, _ = profile_cell(args.arch, args.shape, args.multi_pod, args.top)
    render(prof)
