"""Paper §3 / Fig. 3 reproduction: non-parallel vs parallel dropout on MNIST.

Paper numbers (real MNIST, 10k iters): non-parallel 0.9535, parallel (20
workers x batch 5, AllReduce, same global batch 100) 0.9713 — parallel
*trains better*.  We reproduce the comparison at equal hyperparameters.

Deviation note (recorded in EXPERIMENTS.md): the paper's eta=0.3 diverges
with our init + (synthetic-fallback) data — with momentum 0.98 its effective
step is 0.3/(1-0.98)=15.  We use eta=0.005, mu=0.98 (the paper's momentum,
largest stable eta) for BOTH arms, so the comparison stays apples-to-apples.
"""
from __future__ import annotations

import json
import time


def run(num_steps: int = 2000, eval_every: int = 500, quick: bool = False):
    from repro.core.collective_trainer import paper_comparison
    if quick:
        num_steps, eval_every = 600, 300
    t0 = time.time()
    res = paper_comparison(num_steps=num_steps, eval_every=eval_every,
                           lr=0.005, momentum=0.98, n_train=10000)
    wall = time.time() - t0
    np_acc = res["non_parallel"].final_accuracy
    p_acc = res["parallel"].final_accuracy
    rows = [
        ("mnist_nonparallel_dropout", wall / 2 * 1e6 / num_steps,
         f"acc={np_acc:.4f}"),
        ("mnist_parallel_dropout_20x5", wall / 2 * 1e6 / num_steps,
         f"acc={p_acc:.4f}"),
        ("mnist_parallel_minus_nonparallel", 0.0,
         f"delta={p_acc - np_acc:+.4f} (paper: +0.0178)"),
    ]
    detail = {k: v.row() for k, v in res.items()}
    return rows, detail


if __name__ == "__main__":
    rows, detail = run()
    for r in rows:
        print(",".join(str(x) for x in r))
    print(json.dumps(detail, indent=1))
