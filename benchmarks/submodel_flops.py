"""Paper §2 claim: irregular sub-model partitioning "reduce[s] the size of
model [and] improve[s] the computing performance".

We quantify both on the TPU-adapted implementation:
  * FLOP reduction — fraction of MXU tiles the dropout_matmul kernel skips
    (exact, from the mask; = 1 - keep at steady state).
  * Wall-time — dense einsum vs mask-aware kernel in interpret mode is NOT a
    TPU timing; instead we report the analytic tile-skip ratio plus the
    *memory* saving of the sub-model (weights touched).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def run():
    from repro.core.parallel_dropout import group_block_mask
    rows = []
    for keep in (0.8, 0.5, 0.25):
        G, units, block = 8, 8192, 128
        m = group_block_mask(jax.random.key(0), G, units, keep, block)
        skipped = float((np.asarray(m) == 0).mean())
        # each skipped 128-block skips K/bk MXU tiles in the kernel's K loop
        rows.append((f"submodel_tile_skip_keep{keep}", 0.0,
                     f"skipped_frac={skipped:.3f} flops_saved={skipped:.3f}"))
    # sub-model weight footprint (units kept x d): memory claim
    for keep in (0.5,):
        rows.append((f"submodel_weight_touch_keep{keep}", 0.0,
                     f"weights_touched_frac={keep:.2f}"))
    return rows, {}


if __name__ == "__main__":
    for r in run()[0]:
        print(",".join(str(x) for x in r))
