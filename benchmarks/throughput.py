"""Paper §3 timing claim: "Both took 30 minutes or less until 10,000
iterations" (2016 CPU cluster, 20 workers).  We measure our steps/s for the
same experiment shape on this container's single CPU core and derive the
projected 10k-iteration wall time.  Also measures the LM train-step
throughput of the smallest assigned arch (reduced config) as the modern
substrate datapoint.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def run():
    rows = []
    # --- paper's MNIST shape: 20 groups x batch 5 ---
    from repro.core.collective_trainer import train_mnist
    t0 = time.time()
    steps = 200
    train_mnist(num_groups=20, batch_per_group=5, num_steps=steps,
                eval_every=steps, n_train=2000, hidden=512, lr=0.005)
    dt = time.time() - t0
    per = dt / steps
    rows.append(("mnist_20x5_step", per * 1e6,
                 f"10k_iters_proj={per * 10000 / 60:.1f}min (paper: <=30min "
                 f"on 20-node 2016 cluster)"))

    # --- LM train step (reduced qwen3) ---
    from repro.configs.base import (HornConfig, RunConfig, ShapeConfig,
                                    get_model_config, reduced)
    from repro.core import steps as S
    from repro.launch.mesh import make_test_mesh
    cfg = reduced(get_model_config("qwen3-1.7b"))
    run_cfg = RunConfig(model=cfg, shape=ShapeConfig("b", "train", 256, 8),
                        horn=HornConfig(enabled=True), optimizer="adamw",
                        learning_rate=1e-3)
    step_fn, sh = S.make_train_step(run_cfg, make_test_mesh())
    state = jax.jit(lambda k: S.init_state(k, run_cfg))(jax.random.key(0))
    batch = {"tokens": jnp.ones((8, 256), jnp.int32),
             "labels": jnp.ones((8, 256), jnp.int32)}
    state, _ = step_fn(state, batch)          # compile
    t0 = time.time()
    n = 5
    for _ in range(n):
        state, m = step_fn(state, batch)
    jax.block_until_ready(m["loss"])
    per = (time.time() - t0) / n
    rows.append(("lm_train_step_qwen3_reduced", per * 1e6,
                 f"tok_per_s={8 * 256 / per:,.0f} (1 CPU core)"))
    return rows, {}


if __name__ == "__main__":
    for r in run()[0]:
        print(",".join(str(x) for x in r))
