"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * mnist_repro      — paper §3 / Fig. 3 (parallel vs non-parallel dropout)
  * throughput       — paper §3 timing claim (30 min / 10k iters)
  * submodel_flops   — paper §2 compute/memory-saving claim
  * roofline         — §Roofline terms from the multi-pod dry-run artifacts

``python -m benchmarks.run [--quick]``
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip", default="", help="comma-list of benches to skip")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    from benchmarks import mnist_repro, roofline, submodel_flops, throughput
    benches = [
        ("mnist_repro", lambda: mnist_repro.run(quick=args.quick)),
        ("throughput", throughput.run),
        ("submodel_flops", submodel_flops.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if name in skip:
            continue
        t0 = time.time()
        try:
            rows, _detail = fn()
            for r in rows:
                print(",".join(str(x) for x in r))
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
