"""Perf-regression gate: replay pinned traffic traces, compare against
the committed ``BENCH_serving.json`` trajectory, fail on regressions.

    PYTHONPATH=src python benchmarks/regression.py            # gate
    PYTHONPATH=src python benchmarks/regression.py --regen    # rebuild traces
    PYTHONPATH=src python benchmarks/regression.py --update   # rebase baselines
    PYTHONPATH=src python benchmarks/regression.py --inject recompile  # must FAIL

Three pinned traces under ``benchmarks/traces/`` (versioned JSONL, see
``serving/observability/replay.py``), each stressing a different engine
subsystem:

  decode_heavy       short prompts, long generations, speculative
                     decoding (K=4, prefix cache off) — the accept-rate
                     and decode-throughput gate
  shared_prefix      3/4 of every prompt pinned to one system prefix,
                     prefix cache on — the cache-hit and TTFT gate
  bursty_multiclass  two request bursts across interactive/batch SLO
                     classes — the TTFT-p99 tail and SLO gate

Per trace the harness: builds the engine the trace's header meta
specifies, replays until a warmup replay mints no new jit compile cells
(the deterministic analogue of serving_bench's width sweep — the prefix
cache reaches steady state at the same time), then measures replay A
and replay B.  Gates:

  * A and B byte-identical: same token-stream SHA-256 and identical
    trace-derived (virtual-clock) TTFT/latency — the determinism check.
  * decode tok/s (pooled-p10 tick estimator, NOT wall clock) at least
    ``--min-tok-s-ratio`` of the committed baseline.  The loose default
    absorbs CI-machine variance while still catching order-of-magnitude
    stalls like a forced per-tick recompile.
  * virtual-clock TTFT p99 within ``--max-ttft-ratio`` of baseline
    (deterministic, so this is tight).
  * accept rate within ``--max-accept-drop`` of baseline (speculative
    traces only).
  * zero post-warmup jit compiles (a late compile after a converged
    warmup is always a regression under a deterministic replay).

A committed-digest mismatch is reported but does not fail the gate:
legitimate numeric changes (kernel rewrites) move the streams; the
*within-run* A==B identity is the invariant.  ``--report`` and
``--alert-log`` write the replay report and the structured anomaly
alerts (the CI artifacts); per-trace Chrome traces (with alert instants
and the engine-config metadata block) go to ``--trace-export-dir``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

TRACES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "traces")
DEFAULT_BENCH = os.path.join(os.path.dirname(TRACES_DIR),
                             "..", "BENCH_serving.json")
DEFAULT_ARCH = "qwen3-1.7b"
TICK_DT = 0.01

# Engine knobs per trace live in the trace header meta so a pinned file
# is self-describing; these specs are only consulted by --regen.
TRACE_SPECS = {
    "decode_heavy": {
        "engine": dict(arch=DEFAULT_ARCH, slots=4, pages=192, page_size=8,
                       max_prompt=16, gen=14, budget=64,
                       policy="on_demand", prefix_cache=False,
                       speculate_k=4, draft_keep=0.875,
                       kv_dtype="float32", compute_dtype="float32",
                       seed=0),
        "workload": dict(kind="decode_heavy", n=20, rate=40.0, seed=101),
    },
    "shared_prefix": {
        "engine": dict(arch=DEFAULT_ARCH, slots=4, pages=192, page_size=8,
                       max_prompt=32, gen=10, budget=64,
                       policy="on_demand", prefix_cache=True,
                       speculate_k=0, kv_dtype="float32",
                       compute_dtype="float32", seed=0),
        "workload": dict(kind="shared_prefix", n=24, rate=32.0,
                         shared=24, seed=202),
    },
    "bursty_multiclass": {
        "engine": dict(arch=DEFAULT_ARCH, slots=4, pages=192, page_size=8,
                       max_prompt=24, gen=8, budget=64,
                       policy="on_demand", prefix_cache=True,
                       speculate_k=0, kv_dtype="float32",
                       compute_dtype="float32", seed=0,
                       slo_classes=["interactive:0.05:0.6", "batch:-:3.0"]),
        "workload": dict(kind="bursty_multiclass", n=20, seed=303),
    },
}

GATES = dict(min_tok_s_ratio=0.25, max_ttft_ratio=1.10,
             max_accept_drop=0.05, max_post_warm_compiles=0)


# -- trace generation (--regen) ----------------------------------------------
def _gen_records(spec: dict, vocab_size: int):
    from repro.serving.observability import TraceRecord
    w = spec["workload"]
    rng = np.random.default_rng(w["seed"])
    recs = []
    if w["kind"] == "decode_heavy":
        t = 0.0
        for _ in range(w["n"]):
            t += rng.exponential(1.0 / w["rate"])
            plen = int(rng.integers(4, 11))
            recs.append(TraceRecord(
                arrival_s=t,
                prompt=list(rng.integers(1, vocab_size, plen)),
                max_new_tokens=int(rng.integers(10, 15))))
    elif w["kind"] == "shared_prefix":
        system = list(rng.integers(1, vocab_size, w["shared"]))
        t = 0.0
        for _ in range(w["n"]):
            t += rng.exponential(1.0 / w["rate"])
            tail = list(rng.integers(1, vocab_size,
                                     int(rng.integers(4, 9))))
            recs.append(TraceRecord(
                arrival_s=t, prompt=system + tail,
                max_new_tokens=int(rng.integers(6, 11))))
    elif w["kind"] == "bursty_multiclass":
        # two bursts; interactive requests are short, batch ones long —
        # the tail the burn-rate/SLO gates watch
        for burst_t in (0.0, 0.5):
            for i in range(w["n"] // 2):
                interactive = i % 2 == 0
                plen = int(rng.integers(4, 9)) if interactive \
                    else int(rng.integers(12, 25))
                recs.append(TraceRecord(
                    arrival_s=burst_t + 0.001 * i,
                    prompt=list(rng.integers(1, vocab_size, plen)),
                    max_new_tokens=int(rng.integers(3, 6)) if interactive
                    else int(rng.integers(6, 9)),
                    slo_class="interactive" if interactive else "batch"))
    else:
        raise ValueError(f"unknown workload kind {w['kind']!r}")
    return recs


def regen_traces(names) -> None:
    from repro.configs.base import get_model_config, reduced
    from repro.serving.observability import save_trace
    os.makedirs(TRACES_DIR, exist_ok=True)
    for name in names:
        spec = TRACE_SPECS[name]
        cfg = reduced(get_model_config(spec["engine"]["arch"]))
        recs = _gen_records(spec, cfg.vocab_size)
        meta = {"name": name, "tick_dt": TICK_DT, **spec["engine"]}
        path = os.path.join(TRACES_DIR, f"{name}.jsonl")
        n = save_trace(path, recs, meta)
        print(f"regen: {n} requests -> {path}")


# -- engine construction from trace meta -------------------------------------
def build_engine(meta: dict, _params_cache={}):
    import jax
    from repro.configs.base import get_model_config, reduced
    from repro.launch.serve import build_draft
    from repro.models import api
    from repro.serving import Engine, EngineConfig, Telemetry
    from repro.serving.observability import parse_slo_class

    arch, seed = meta["arch"], int(meta.get("seed", 0))
    cfg = reduced(get_model_config(arch))
    key = (arch, seed)
    if key not in _params_cache:
        _params_cache.clear()
        _params_cache[key] = api.model_init(jax.random.key(seed), cfg)
    params = _params_cache[key]
    ecfg = EngineConfig(
        num_slots=int(meta["slots"]), num_pages=int(meta["pages"]),
        page_size=int(meta["page_size"]),
        max_prompt_len=-(-int(meta["max_prompt"]) // int(meta["page_size"]))
        * int(meta["page_size"]),
        max_new_tokens=int(meta["gen"]),
        token_budget=max(int(meta["budget"]), int(meta["slots"])),
        seed=seed, policy=meta.get("policy", "on_demand"),
        prefix_cache=bool(meta.get("prefix_cache", True)),
        speculate_k=int(meta.get("speculate_k", 0)),
        kv_dtype=meta.get("kv_dtype", "float32"),
        compute_dtype=meta.get("compute_dtype", "float32"))
    telemetry = Telemetry(
        timeline=True, trace_maxlen=None,
        slo_classes=[parse_slo_class(s)
                     for s in meta.get("slo_classes", [])])
    draft = build_draft(cfg, params, None, speculate=ecfg.speculate_k,
                        draft_circuit=0,
                        draft_keep=float(meta.get("draft_keep", 0.875)),
                        mask_block=16, seed=seed)
    return Engine(cfg, params, ecfg, draft=draft, telemetry=telemetry)


# -- fault injection (--inject) ----------------------------------------------
def apply_injection(engine, inject: str) -> None:
    """Wrap the engine's device step with a deliberate slowdown so the
    gate can prove it fails when it should.  ``recompile`` flushes the
    jit caches before every call (the classic silent regression);
    ``sleep:MS`` stalls the host path per tick (a spike-detector and
    throughput regression)."""
    import time as _time

    import jax
    inner = engine._step
    if inject == "recompile":
        def hurt(*a, **kw):
            jax.clear_caches()
            return inner(*a, **kw)
    elif inject.startswith("sleep:"):
        delay = float(inject.split(":", 1)[1]) / 1e3

        def hurt(*a, **kw):
            _time.sleep(delay)
            return inner(*a, **kw)
    else:
        raise ValueError(f"unknown injection {inject!r} "
                         f"(want 'recompile' or 'sleep:MS')")
    engine._step = hurt


# -- one trace: warmup + 2 measured replays ----------------------------------
def replay_trace(name: str, path: str, *, inject=None,
                 trace_export_dir=None, max_warmups: int = 4) -> dict:
    from repro.serving.observability import (load_trace, replay,
                                             validate_chrome_trace)
    records, meta = load_trace(path)
    tick_dt = float(meta.get("tick_dt", TICK_DT))
    engine = build_engine(meta)
    prof = engine.obs.profiler
    # deterministic warmup: replay until a pass mints no new compile
    # cell (the prefix cache reaches steady state at the same point)
    warmups = 0
    for _ in range(max_warmups):
        replay(engine, records, tick_dt=tick_dt)
        warmups += 1
        if prof is None or prof.compiles_total == 0:
            break
    # the fault lands AFTER warmup, the way a real silent regression
    # would: the warmed path degrades, so every injected compile is
    # post-warm and the tok/s collapse is measured against warm ticks
    if inject:
        apply_injection(engine, inject)
    a = replay(engine, records, tick_dt=tick_dt)
    post_warm = prof.compiles_post_warm if prof is not None else 0
    cost = prof.cost_report() if prof is not None else {}
    # the B replay exists only for the determinism gate; under an
    # injected fault (a single replay can cost minutes) skip it — the
    # timeline then still holds A's alert instants for the export
    b = a if inject else replay(engine, records, tick_dt=tick_dt)
    out = {
        "trace": name,
        "warmup_replays": warmups,
        "summary": a.summary(),
        "determinism": {
            "digest_a": a.token_digest,
            "digest_b": b.token_digest,
            "byte_identical": a.token_digest == b.token_digest,
            "ttft_identical": a.ttft_s == b.ttft_s,
            "latency_identical": a.latency_s == b.latency_s,
        },
        "post_warm_compiles": post_warm,
        "cost": cost,
        "alerts": a.alerts + (b.alerts if b is not a else []),
    }
    if trace_export_dir:
        os.makedirs(trace_export_dir, exist_ok=True)
        dest = os.path.join(trace_export_dir, f"{name}.trace.json")
        engine.obs.timeline.export(dest)
        with open(dest) as f:
            validate_chrome_trace(json.load(f))
        out["trace_export"] = dest
    return out


# -- gating -------------------------------------------------------------------
def evaluate_gates(result: dict, baseline: dict, gates: dict) -> list:
    """Returns a list of failure strings (empty = pass)."""
    fails = []
    det = result["determinism"]
    if not det["byte_identical"]:
        fails.append("replay A and B token streams differ "
                     f"({det['digest_a'][:12]} != {det['digest_b'][:12]})")
    if not det["ttft_identical"] or not det["latency_identical"]:
        fails.append("trace-derived TTFT/latency differ between replays")
    if result["post_warm_compiles"] > gates["max_post_warm_compiles"]:
        fails.append(f"{result['post_warm_compiles']} post-warmup jit "
                     f"compile(s) (limit "
                     f"{gates['max_post_warm_compiles']})")
    s = result["summary"]
    if baseline:
        base_tok = baseline.get("decode_tok_s_p10")
        if base_tok and s.get("decode_tok_s_p10"):
            ratio = s["decode_tok_s_p10"] / base_tok
            if ratio < gates["min_tok_s_ratio"]:
                fails.append(
                    f"decode tok/s {s['decode_tok_s_p10']:.1f} is "
                    f"{ratio:.2f}x baseline {base_tok:.1f} (floor "
                    f"{gates['min_tok_s_ratio']}x)")
        base_ttft = baseline.get("ttft_p99_s")
        if base_ttft and s.get("ttft_p99_s"):
            if s["ttft_p99_s"] > base_ttft * gates["max_ttft_ratio"]:
                fails.append(
                    f"TTFT p99 {s['ttft_p99_s']:.3f}s > "
                    f"{gates['max_ttft_ratio']}x baseline "
                    f"{base_ttft:.3f}s")
        base_acc = baseline.get("accept_rate", 0.0)
        if base_acc > 0:
            if s.get("accept_rate", 0.0) < base_acc \
                    - gates["max_accept_drop"]:
                fails.append(
                    f"accept rate {s.get('accept_rate', 0.0):.3f} fell "
                    f"more than {gates['max_accept_drop']} below "
                    f"baseline {base_acc:.3f}")
        if baseline.get("token_digest") and \
                baseline["token_digest"] != s["token_digest"]:
            # informational: numeric changes legitimately move streams;
            # --update rebaselines
            result.setdefault("warnings", []).append(
                "token digest differs from committed baseline "
                "(rebase with --update if intended)")
    return fails


def baseline_entry(result: dict) -> dict:
    """What gets committed to BENCH_serving.json per trace."""
    s = result["summary"]
    return {k: s[k] for k in ("token_digest", "decode_tok_s_p10",
                              "ttft_p99_s", "latency_p99_s",
                              "accept_rate", "ticks",
                              "generated_tokens")}


def replay_phase(names=None, *, inject=None, trace_export_dir=None) -> dict:
    """All pinned traces replayed — the ``replay`` phase serving_bench
    embeds in a regenerated BENCH_serving.json, and the body of the
    regression gate."""
    names = list(names or sorted(TRACE_SPECS))
    out = {}
    for name in names:
        path = os.path.join(TRACES_DIR, f"{name}.jsonl")
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"{path} missing — run regression.py --regen")
        out[name] = replay_trace(name, path, inject=inject,
                                 trace_export_dir=trace_export_dir)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", action="append", default=[],
                    choices=sorted(TRACE_SPECS),
                    help="subset of traces (default: all)")
    ap.add_argument("--bench", default=os.path.normpath(DEFAULT_BENCH),
                    help="committed BENCH_serving.json with the 'replay' "
                         "baseline block")
    ap.add_argument("--regen", action="store_true",
                    help="regenerate the pinned trace files and exit")
    ap.add_argument("--update", action="store_true",
                    help="write this run's numbers into --bench as the "
                         "new baselines")
    ap.add_argument("--inject", default=None, metavar="FAULT",
                    help="deliberate slowdown: 'recompile' or 'sleep:MS' "
                         "(the gate must fail)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the full replay report JSON")
    ap.add_argument("--alert-log", default=None, metavar="PATH",
                    help="write the structured anomaly alerts JSON")
    ap.add_argument("--trace-export-dir", default=None, metavar="DIR",
                    help="export per-trace Chrome traces (schema-"
                         "validated, with alert instants)")
    ap.add_argument("--min-tok-s-ratio", type=float,
                    default=GATES["min_tok_s_ratio"])
    ap.add_argument("--max-ttft-ratio", type=float,
                    default=GATES["max_ttft_ratio"])
    ap.add_argument("--max-accept-drop", type=float,
                    default=GATES["max_accept_drop"])
    ap.add_argument("--max-post-warm-compiles", type=int,
                    default=GATES["max_post_warm_compiles"])
    args = ap.parse_args()
    names = args.trace or sorted(TRACE_SPECS)

    if args.regen:
        regen_traces(names)
        return

    gates = dict(min_tok_s_ratio=args.min_tok_s_ratio,
                 max_ttft_ratio=args.max_ttft_ratio,
                 max_accept_drop=args.max_accept_drop,
                 max_post_warm_compiles=args.max_post_warm_compiles)
    bench = {}
    if os.path.exists(args.bench):
        with open(args.bench) as f:
            bench = json.load(f)
    baselines = bench.get("replay", {})

    results = replay_phase(names, inject=args.inject,
                           trace_export_dir=args.trace_export_dir)
    failures = {}
    for name, res in results.items():
        fails = evaluate_gates(res, baselines.get(name, {}), gates)
        res["gate_failures"] = fails
        if fails:
            failures[name] = fails
        s = res["summary"]
        verdict = "FAIL" if fails else "ok"
        print(f"[{verdict}] {name}: {s['generated_tokens']} tok in "
              f"{s['ticks']} ticks, {s['decode_tok_s_p10'] or 0:.1f} "
              f"tok/s (p10), ttft p99 {s['ttft_p99_s']}s, accept "
              f"{s['accept_rate']:.2f}, digest {s['token_digest'][:12]}, "
              f"{res['post_warm_compiles']} post-warm compiles, "
              f"{len(res['alerts'])} alert(s)")
        for w in res.get("warnings", []):
            print(f"    warn: {w}")
        for msg in fails:
            print(f"    FAIL: {msg}")

    if args.report:
        with open(args.report, "w") as f:
            json.dump({"gates": gates, "results": results,
                       "failures": failures}, f, indent=1, sort_keys=True)
        print(f"report -> {args.report}")
    if args.alert_log:
        with open(args.alert_log, "w") as f:
            json.dump({name: res["alerts"]
                       for name, res in results.items()}, f, indent=1)
        print(f"alert log -> {args.alert_log}")

    if args.update:
        bench["replay"] = {name: baseline_entry(res)
                           for name, res in results.items()}
        with open(args.bench, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baselines updated -> {args.bench}")
        return

    if failures:
        print(f"\nregression gate FAILED for {len(failures)} trace(s)")
        sys.exit(1)
    print("\nregression gate passed")


if __name__ == "__main__":
    main()
