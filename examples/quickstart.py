"""Quickstart: Horn parallel dropout in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's neuron-centric MNIST network, trains it for a few hundred
steps with 8 worker groups x parallel dropout, and prints the accuracy.
"""
import jax

from repro.configs.base import HornConfig, TopologyConfig
from repro.core.collective_trainer import train_mnist
from repro.core.neuron_centric import NeuronNetwork

# --- the paper's programming model: addLayer(units, activation, neuron) ----
nn = NeuronNetwork(input_units=784, input_neuron="dropout", input_keep=0.8)
nn.add_layer(512, "relu", neuron="dropout", keep=0.5)   # DropoutNeuron.class
nn.add_layer(512, "relu", neuron="dropout", keep=0.5)
nn.add_layer(10, "identity")                             # softmax head in loss
print("neuron-centric net:", [l.units for l in nn.layers])

# --- collective & parallel dropout training (8 groups, batch averaging) ----
result = train_mnist(
    num_groups=8, batch_per_group=12, num_steps=600, eval_every=200,
    lr=0.005, momentum=0.98, hidden=512, depth=2, n_train=8000,
    horn_cfg=HornConfig(enabled=True, num_groups=8, block_size=1),
    topology=TopologyConfig(kind="allreduce"),
    name="quickstart-8-groups")

print(f"data: {result.data_source}")
for s, a in zip(result.steps, result.accuracy):
    print(f"  step {s:5d}  accuracy {a:.4f}")
print(f"final accuracy: {result.final_accuracy:.4f}")
