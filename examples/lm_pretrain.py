"""End-to-end LM pretraining driver with Horn parallel dropout.

    PYTHONPATH=src python examples/lm_pretrain.py --scale 20m --steps 300

Full production path: config -> pjit train step (Horn masks on) -> sharded
deterministic pipeline -> async checkpoints -> preemption-safe loop.  The
``--scale 100m`` config is the deliverable's ~100M-parameter model; on this
1-core CPU container the default is 20m so a few hundred steps finish in
reasonable wall time (the 100m config is the same code path, proven by the
dry-run at full scale).
"""
import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.base import (ATTN, HornConfig, ModelConfig, RunConfig,
                                ShapeConfig, TopologyConfig)
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import steps as S
from repro.data.pipeline import SyntheticTokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_test_mesh
from repro.runtime.fault_tolerance import fault_tolerant_loop, PreemptionHandler

SCALES = {
    "2m": dict(num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
               head_dim=32, d_ff=512, vocab_size=4096),
    "20m": dict(num_layers=8, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1536, vocab_size=8192),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="20m", choices=SCALES)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--horn", action="store_true", default=True)
    ap.add_argument("--no-horn", dest="horn", action="store_false")
    ap.add_argument("--ckpt", default="ckpt_lm")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"horn-lm-{args.scale}", family="dense",
                      layer_pattern=(ATTN,), qk_norm=True, **SCALES[args.scale])
    print(f"{cfg.name}: {cfg.param_count():,} params, horn={args.horn}")
    run = RunConfig(
        model=cfg, shape=ShapeConfig("pretrain", "train", args.seq, args.batch),
        horn=HornConfig(enabled=args.horn, num_groups=4, keep_hidden=0.9,
                        keep_input=0.95),
        optimizer="adamw", learning_rate=3e-4)
    mesh = make_test_mesh()
    step_fn, sh = S.make_train_step(run, mesh)
    state = jax.jit(lambda k: S.init_state(k, run))(jax.random.key(0))
    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))

    hist = []
    t0 = time.time()

    def on_metrics(step, metrics):
        hist.append((step, float(metrics["loss"])))
        if step % args.log_every == 0:
            tok = step * args.batch * args.seq / max(time.time() - t0, 1e-9)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"({tok:,.0f} tok/s)", flush=True)

    ck = Checkpointer(args.ckpt)
    state, last, reason = fault_tolerant_loop(
        state=state, step_fn=step_fn, batch_at=pipe.batch_at,
        checkpointer=ck, num_steps=args.steps, checkpoint_every=100,
        state_shardings=sh["state"],
        preemption=PreemptionHandler(), on_metrics=on_metrics)
    print(f"exit={reason} step={last} "
          f"loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")
    with open(f"lm_pretrain_{args.scale}_horn{int(args.horn)}.json", "w") as f:
        json.dump({"scale": args.scale, "horn": args.horn, "history": hist,
                   "exit": reason}, f)


if __name__ == "__main__":
    main()
