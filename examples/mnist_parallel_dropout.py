"""Paper §3 experiment, full driver: non-parallel vs parallel vs local-SGD
(Downpour-style) vs int8-compressed merges — every Horn topology on MNIST.

    PYTHONPATH=src python examples/mnist_parallel_dropout.py [--steps 2000]
"""
import argparse
import json

from repro.configs.base import HornConfig, TopologyConfig
from repro.core.collective_trainer import train_mnist
from repro.data.mnist import load_mnist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--eval-every", type=int, default=500)
    args = ap.parse_args()
    data = load_mnist(n_train=10000)
    common = dict(num_steps=args.steps, eval_every=args.eval_every,
                  lr=0.005, momentum=0.98, data=data)

    runs = [
        ("non-parallel (1x100)",
         dict(num_groups=1, batch_per_group=100)),
        ("parallel 20x5 AllReduce (paper)",
         dict(num_groups=20, batch_per_group=5)),
        ("parallel 20x5 local-SGD H=8 (Downpour analogue)",
         dict(num_groups=20, batch_per_group=5,
              topology=TopologyConfig(kind="local_sgd", local_sgd_period=8))),
        ("parallel 20x5 int8-compressed merge",
         dict(num_groups=20, batch_per_group=5,
              topology=TopologyConfig(kind="allreduce",
                                      grad_compression="int8"))),
        ("parallel 20x5, NO dropout (ablation)",
         dict(num_groups=20, batch_per_group=5,
              horn_cfg=HornConfig(enabled=False))),
    ]
    results = {}
    for name, kw in runs:
        res = train_mnist(name=name, **common, **kw)
        results[name] = res.row()
        print(f"{name:50s} final_acc={res.final_accuracy:.4f} "
              f"curve={[round(a, 3) for a in res.accuracy]}")
    with open("mnist_results.json", "w") as f:
        json.dump(results, f, indent=1)
    print("wrote mnist_results.json  (paper: 0.9535 vs 0.9713 @10k iters)")


if __name__ == "__main__":
    main()
