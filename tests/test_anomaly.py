"""Anomaly-detector and metrics-cardinality tests on synthetic streams.

The acceptance-critical properties pinned here:
  * the tick-spike detector fires on an injected stall, does NOT fire
    on constant-duration jitter (MAD floor) or during its warm-up
    window, and rate-limits a sustained stall to one alert per episode;
  * the SLO burn-rate detector fires exactly when the violation
    fraction clears the burn threshold in BOTH windows — a short burst
    alone or a diluted long-window alone stays silent;
  * the pool-leak watchdog is SILENT on copy-on-write / fork-heavy
    traffic (shared pages counted once via distinct page ids) and fires
    on a genuinely unreachable page;
  * the accept-collapse detector needs a healthy baseline first, fires
    once per collapse episode, and re-arms on recovery;
  * metric label views are bounded: labels past the cap fold into an
    explicit ``overflow`` bucket, totals are preserved exactly, and the
    registry counts the folds.
"""
import pytest

from repro.serving.kv_cache import PagePool
from repro.serving.observability import (ACCEPT_COLLAPSE, OVERFLOW_LABEL,
                                         POOL_LEAK, RECOMPILE, SLO_BURN,
                                         TICK_SPIKE, AcceptCollapseDetector,
                                         AnomalyMonitor, BurnRateDetector,
                                         Counter, Histogram, MetricsRegistry,
                                         PoolLeakWatchdog, TickSpikeDetector)


# ---------------------------------------------------------------------------
# tick-spike detector
# ---------------------------------------------------------------------------
def test_spike_fires_on_stall_not_on_jitter():
    det = TickSpikeDetector(min_samples=24, cooldown=16)
    # healthy stream: ~2ms ticks with +-5% deterministic jitter
    for i in range(60):
        dur = 0.002 * (1.0 + 0.05 * ((-1) ** i))
        assert det.observe(i, dur) is None, f"jitter fired at tick {i}"
    hit = det.observe(60, 0.150)                   # a 75x stall
    assert hit is not None and hit["dur_s"] == 0.150
    assert hit["z"] > 8.0


def test_spike_warmup_window_and_cooldown():
    det = TickSpikeDetector(min_samples=24, cooldown=16)
    # during warm-up even a huge tick must not fire (no baseline yet)
    for i in range(23):
        assert det.observe(i, 0.002 if i else 1.0) is None
    for i in range(23, 50):
        det.observe(i, 0.002)
    # a sustained stall: first spike fires, the rest sit in cooldown
    assert det.observe(50, 0.5) is not None
    assert det.observe(51, 0.5) is None
    assert det.observe(60, 0.5) is None
    assert det.observe(66, 0.5) is not None        # cooldown elapsed


def test_spike_does_not_poison_its_own_baseline():
    det = TickSpikeDetector(min_samples=24, cooldown=0)
    for i in range(30):
        det.observe(i, 0.002)
    n = len(det.win)
    assert det.observe(30, 1.0) is not None
    # the anomalous tick must NOT enter the rolling window
    assert len(det.win) == n and max(det.win) < 0.01


# ---------------------------------------------------------------------------
# SLO burn rate
# ---------------------------------------------------------------------------
def test_burn_rate_fires_only_when_both_windows_burn():
    # budget 10%, threshold 2x => needs >= 20% violations in BOTH the
    # 4-sample short window and the 12-sample long window
    det = BurnRateDetector(budget=0.1, burn_thresh=2.0, short_window=4,
                           long_window=12, min_samples=4)
    # a short hot burst right at the start: long window is equally hot,
    # but nothing may fire before min_samples observations
    assert det.observe(True) is None
    assert det.observe(True) is None
    assert det.observe(True) is None
    hit = det.observe(True)                        # 4th: both windows 100%
    assert hit is not None
    assert hit["short_burn"] == 10.0 and hit["long_burn"] == 10.0
    # windows were cleared: the same episode does not re-fire
    assert det.observe(True) is None


def test_burn_rate_short_burst_diluted_by_long_window_stays_silent():
    det = BurnRateDetector(budget=0.1, burn_thresh=2.0, short_window=4,
                           long_window=12, min_samples=4)
    for _ in range(12):
        assert det.observe(False) is None
    # 1 violation in the short window = 25% short burn (2.5x), but the
    # long window sits at 1/12 (< 2x) -> silent, per the SRE pattern
    assert det.observe(True) is None
    for _ in range(3):
        assert det.observe(False) is None


def test_burn_rate_rejects_bad_budget():
    with pytest.raises(ValueError):
        BurnRateDetector(budget=0.0)


# ---------------------------------------------------------------------------
# pool-leak watchdog (against the real PagePool)
# ---------------------------------------------------------------------------
def test_leak_watchdog_silent_on_cow_fork_traffic():
    pool = PagePool(num_pages=32, page_size=4)
    pool.alloc(0, 16)                              # 4 pages
    # fork-heavy: many sequences SHARING the same pages (refcounts go
    # up, distinct page count does not)
    for dst in range(1, 6):
        pool.fork(0, dst)
    # COW: one fork diverges on a shared page
    pool.prepare_write(1, first_token=12, last_token=16)
    dog = PoolLeakWatchdog(every=1)
    assert pool.used_pages == pool.live_table_pages()
    assert dog.check(0, pool.used_pages, pool.live_table_pages()) is None
    # release the forks again — still balanced
    for dst in range(1, 6):
        pool.free_seq(dst)
    assert dog.check(1, pool.used_pages, pool.live_table_pages()) is None


def test_leak_watchdog_fires_on_unreachable_pages():
    pool = PagePool(num_pages=16, page_size=4)
    pool.alloc(0, 8)
    pool.alloc(1, 8)
    # simulate a lost ref-release: a table vanishes without freeing its
    # pages, so used_pages stays up while no live table can reach them
    pool._tables.pop(1)
    dog = PoolLeakWatchdog(every=4)
    assert not dog.due(2) and dog.due(3)           # first check after N ticks
    hit = dog.check(3, pool.used_pages, pool.live_table_pages())
    assert hit is not None and hit["leaked_pages"] == 2
    assert not dog.due(6) and dog.due(7)           # cadence honoured


# ---------------------------------------------------------------------------
# accept-rate collapse
# ---------------------------------------------------------------------------
def test_accept_collapse_fires_once_and_rearms_on_recovery():
    det = AcceptCollapseDetector(window=8, min_drafted=32,
                                 collapse_frac=0.5, abs_floor=0.5)
    # healthy baseline: 7/8 accepted
    for _ in range(8):
        assert det.observe(8, 7) is None
    # the draft circuit silently stops agreeing
    fired = [det.observe(8, 0) for _ in range(10)]
    hits = [h for h in fired if h]
    assert len(hits) == 1                          # once per episode
    assert hits[0]["rolling_accept"] < 0.5 * hits[0]["longrun_accept"]
    # recovery re-arms, a second collapse fires again
    for _ in range(16):
        det.observe(8, 8)
    assert any(det.observe(8, 0) for _ in range(10))


def test_accept_collapse_needs_baseline_first():
    det = AcceptCollapseDetector(window=8, min_drafted=64)
    # terrible from the very start: no baseline to collapse FROM
    assert all(det.observe(8, 0) is None for _ in range(32))


# ---------------------------------------------------------------------------
# the monitor facade
# ---------------------------------------------------------------------------
def test_monitor_routes_hooks_to_alerts_and_counts():
    mon = AnomalyMonitor(
        spike=TickSpikeDetector(min_samples=4, cooldown=0),
        burn=dict(budget=0.1, burn_thresh=2.0, short_window=2,
                  long_window=4, min_samples=2),
        accept=AcceptCollapseDetector(window=4, min_drafted=8),
        leak=PoolLeakWatchdog(every=1))
    seen = []
    mon.on_alert = seen.append
    for i in range(8):
        mon.on_tick(i, float(i), 0.002)
    mon.on_tick(8, 8.0, 1.0)                       # spike
    mon.on_tick(9, 9.0, 0.002, used_pages=10, live_pages=lambda: 7)
    for _ in range(2):
        mon.on_finish("interactive", met=False, t=10.0)
    for _ in range(4):
        mon.on_speculate(4, 4, t=11.0)
    for _ in range(8):
        mon.on_speculate(4, 0, t=12.0)
    mon.on_compile("unified_step", "C=8", 1.2, post_warm=False)  # warmup: ok
    mon.on_compile("unified_step", "C=2", 1.2, post_warm=True)   # regression
    kinds = {a.kind for a in seen}
    assert kinds == {TICK_SPIKE, POOL_LEAK, SLO_BURN, ACCEPT_COLLAPSE,
                     RECOMPILE}
    assert mon.counts[RECOMPILE] == 1              # warmup compile ignored
    rep = mon.report()
    assert rep["counts"] == mon.counts
    assert all({"kind", "tick", "t", "severity", "message", "data"}
               <= set(a) for a in rep["alerts"])
    mon.reset()
    assert mon.report() == {"counts": {}, "alerts": []}


# ---------------------------------------------------------------------------
# metrics label-cardinality cap
# ---------------------------------------------------------------------------
def test_counter_label_cap_folds_into_overflow_and_preserves_total():
    c = Counter("tokens", max_labels=3)
    for i in range(10):
        c.inc(2.0, label=f"submodel_{i}")
    # 2 real label views + the explicit overflow bucket, total exact
    view = c.view()
    assert set(view) == {"submodel_0", "submodel_1", OVERFLOW_LABEL}
    assert view[OVERFLOW_LABEL] == 16.0
    assert sum(view.values()) == c.value == 20.0
    assert c.label_overflows == 8
    assert c.summary()["label_overflows"] == 8
    # an already-seen label keeps routing to its own view
    c.inc(1.0, label="submodel_1")
    assert c.view()["submodel_1"] == 3.0


def test_histogram_label_cap_and_overflow_counts():
    h = Histogram("lat", max_labels=2)
    for i in range(6):
        h.observe(0.5, label=f"class_{i}")
    view = h.view()
    assert set(view) == {"class_0", OVERFLOW_LABEL}
    assert view[OVERFLOW_LABEL].count == 5
    assert h.count == 6 and h.label_overflows == 5


def test_registry_attaches_overflow_warning_counter():
    reg = MetricsRegistry(max_labels=2)
    c = reg.counter("by_submodel")
    g = reg.gauge("pool_util")
    for i in range(5):
        c.inc(label=f"s{i}")
        g.set(float(i), label=f"owner{i}")
    warn = reg.get(MetricsRegistry.OVERFLOW_COUNTER)
    # 4 folds from the counter + 4 from the gauge
    assert warn.value == 8.0
    assert warn.view() == {"by_submodel": 4.0, "pool_util": 4.0}
    assert set(g.view()) == {"owner0", OVERFLOW_LABEL}
    # reset clears the per-metric overflow tallies too
    c.reset()
    assert c.label_overflows == 0


def test_unlabelled_metrics_never_touch_the_cap():
    c = Counter("plain", max_labels=1)
    for _ in range(100):
        c.inc()
    assert c.value == 100.0 and c.view() == {} and c.label_overflows == 0
