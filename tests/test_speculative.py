"""Speculative decoding: materialized small-circuit drafts verified by the
parent in one budgeted call.

The load-bearing guarantee is BYTE-IDENTITY: greedy speculative decode must
emit exactly the token stream non-speculative greedy decode emits — solo,
routed over a ModelBank, co-batched with ensembles, under preemption, and
with the prefix cache adopting pages — because the parent verifies every
position it commits (the draft only decides how many positions one tick
can commit).  Temperature > 0 is rejection sampling: distributionally the
parent, not byte-equal to sequential sampling, but byte-REPRODUCIBLE
run-to-run per (req_id, sample_step) fold_in.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs.base import HornConfig, get_model_config, reduced
from repro.models import api
from repro.serving import (Engine, EngineConfig, ModelBank, Router,
                           speculative_draft_len)

CFG = reduced(get_model_config("qwen3-1.7b"), dtype="float32")
# high-keep draft: with UNTRAINED weights, agreement (and so acceptance)
# tracks how much of the FFN the circuit keeps — see ModelBank.draft_model
HORN = HornConfig(enabled=True, keep_hidden=0.875, keep_input=1.0,
                  block_size=16)


@pytest.fixture(scope="module")
def params():
    return api.model_init(jax.random.key(0), CFG)


@pytest.fixture(scope="module")
def draft(params):
    return ModelBank(CFG, HORN, 1, seed=0).draft_model(0, params)


def mk(params, *, spec_k=0, draft=None, bank=None, router=None, **over):
    ec = dict(num_slots=3, num_pages=64, page_size=4, max_prompt_len=32,
              max_new_tokens=12, token_budget=24, policy="on_demand",
              kv_dtype="float32", compute_dtype="float32",
              speculate_k=spec_k)
    ec.update(over)
    return Engine(CFG, params, EngineConfig(**ec), bank=bank,
                  router=router, draft=draft)


def prompts(lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, CFG.vocab_size, (n,)).astype(np.int32)
            for n in lens]


def outs(engine):
    return {r.id: list(r.out_tokens) for r in engine.sched.finished}


def drain(engine, reqs, gen=10, **kw):
    for p in reqs:
        engine.submit(p, gen, **kw)
    engine.run()
    return outs(engine)


# ---------------------------------------------------------------------------
# byte-identity (greedy)
# ---------------------------------------------------------------------------
def test_greedy_solo_byte_identical_and_fewer_ticks(params, draft):
    reqs = prompts((7, 13, 5))
    base = mk(params)
    spec = mk(params, spec_k=4, draft=draft)
    assert drain(base, reqs) == drain(spec, reqs)
    # the whole point: >1 committed token per speculating slot-tick, and
    # strictly fewer engine ticks than sequential decode
    assert spec.accepted_tok_per_tick > 1.0
    assert spec.spec_accepted > 0
    assert spec.steps < base.steps
    spec.pool.check_invariants()
    spec.spec.pool.check_invariants()
    assert spec.spec.pool.num_seqs == 0      # all draft state released


def test_greedy_routed_byte_identical(params):
    reqs = prompts((7, 13, 5, 9))
    bank = ModelBank(CFG, HORN, 3, seed=0)
    base = mk(params, bank=ModelBank(CFG, HORN, 3, seed=0),
              router=Router(3, policy="explicit"))
    spec = mk(params, spec_k=4, bank=bank,
              router=Router(3, policy="explicit"),
              draft=bank.draft_model(0, params))
    for eng in (base, spec):
        for i, p in enumerate(reqs):
            eng.submit(p, 8, submodel_id=i % 3)
        eng.run()
    assert outs(base) == outs(spec)
    assert spec.spec_accepted >= 0           # drafts verified under each
    assert spec.accepted_tok_per_tick >= 1.0  # slot's own circuit masks


def test_greedy_under_preemption_byte_identical(params, draft):
    # a pool tight enough that the SPECULATING engine preempts too: the
    # rollback/truncate path and the preempt path must compose
    reqs = prompts((6, 9, 7, 8), seed=3)
    kw = dict(num_pages=12, max_prompt_len=16, token_budget=16,
              max_new_tokens=10)
    base = mk(params, **kw)
    spec = mk(params, spec_k=3, draft=draft, **kw)
    assert drain(base, reqs, gen=9) == drain(spec, reqs, gen=9)
    assert spec.preemptions > 0, "pool not tight enough to test preemption"
    spec.pool.check_invariants()
    assert spec.spec.pool.num_seqs == 0


def test_greedy_with_prefix_cache_and_shared_prompts(params, draft):
    # prefix-cache adoption (mid-prompt prefill start) + verify rollback
    # interleave: truncated draft tails must never reach the publishable
    # region, and cached pages must never leak into a verify chunk
    rng = np.random.default_rng(5)
    system = rng.integers(1, CFG.vocab_size, (12,)).astype(np.int32)
    reqs = [np.concatenate([system,
                            rng.integers(1, CFG.vocab_size, (4 + i,))
                            .astype(np.int32)]) for i in range(3)]
    base = mk(params, prefix_cache=True)
    spec = mk(params, spec_k=4, draft=draft, prefix_cache=True)
    for eng in (base, spec):
        eng.submit(reqs[0], 10)
        eng.run()                  # publish the system prefix first
        for p in reqs[1:]:
            eng.submit(p, 10)
        eng.run()
    assert outs(base) == outs(spec)
    assert spec.cache_hit_tokens > 0, "shared prompts never hit the cache"
    spec.pool.check_invariants()


def test_greedy_cobatched_with_ensemble(params):
    # ensemble members decode in lockstep (never speculate) while a solo
    # slot in the same tick verifies drafts — one jitted call carries both
    bank = ModelBank(CFG, HORN, 3, seed=0)
    rng = np.random.default_rng(7)
    pe = rng.integers(1, CFG.vocab_size, (9,)).astype(np.int32)
    ps = rng.integers(1, CFG.vocab_size, (6,)).astype(np.int32)
    streams = []
    for spec_k in (0, 4):
        eng = mk(params, spec_k=spec_k, bank=ModelBank(CFG, HORN, 3, seed=0),
                 router=Router(3),
                 draft=bank.draft_model(0, params) if spec_k else None,
                 num_slots=5, num_pages=96, token_budget=40)
        g = eng.submit(pe, 8, ensemble="mean_logit")
        eng.submit(ps, 8)
        eng.run()
        streams.append((list(g.out_tokens), outs(eng)))
    assert streams[0] == streams[1]


def test_eos_mid_verify_window_stops_exactly(params, draft):
    # pick an EOS the baseline emits mid-stream, then check the
    # speculative engine truncates its commits at exactly that token
    reqs = prompts((7,), seed=1)
    probe = mk(params)
    stream = drain(probe, reqs)[0]
    eos = stream[len(stream) // 2]
    base = mk(params, eos_id=eos)
    spec = mk(params, spec_k=4, draft=draft, eos_id=eos)
    assert drain(base, reqs) == drain(spec, reqs)
    done = spec.sched.finished[0]
    assert done.out_tokens[-1] == eos
    assert eos not in done.out_tokens[:-1]


# ---------------------------------------------------------------------------
# temperature > 0: reproducible rejection sampling
# ---------------------------------------------------------------------------
def test_temperature_reproducible_and_clean(params, draft):
    reqs = prompts((7, 13, 5))
    runs = []
    for _ in range(2):
        eng = mk(params, spec_k=4, draft=draft, temperature=0.8)
        runs.append(drain(eng, reqs, gen=8))
        eng.pool.check_invariants()
        eng.spec.pool.check_invariants()
    assert runs[0] == runs[1], "same seeds must replay the same stream"
    assert eng.spec_drafted > 0


def test_temperature_nonspec_path_unchanged_by_plumbing(params):
    # the S_v == 1 window with temperature > 0 must be the classic
    # (req_id, step) fold_in categorical — two fresh engines agree
    reqs = prompts((7, 5))
    a = drain(mk(params, temperature=0.8), reqs, gen=6)
    b = drain(mk(params, temperature=0.8), reqs, gen=6)
    assert a == b


# ---------------------------------------------------------------------------
# budget accounting + validation
# ---------------------------------------------------------------------------
def test_speculative_budget_split():
    # each decode slot costs its pending token; the rest splits across
    # speculating slots, clamped to k and floored at plain decode
    assert speculative_draft_len(4, 24, 3, 3) == 4
    assert speculative_draft_len(4, 6, 3, 3) == 1
    assert speculative_draft_len(4, 3, 3, 3) == 0
    assert speculative_draft_len(4, 24, 3, 0) == 0
    assert speculative_draft_len(0, 24, 3, 3) == 0


def test_budget_pressure_degrades_gracefully(params, draft):
    # token_budget == num_slots: a full decode batch has zero headroom
    # (those ticks run plain decode), but the moment slots free up the
    # leftover budget drafts again — byte-identical throughout
    reqs = prompts((5, 7, 6))
    kw = dict(token_budget=3, num_slots=3)
    base = mk(params, **kw)
    spec = mk(params, spec_k=4, draft=draft, **kw)
    assert drain(base, reqs, gen=6) == drain(spec, reqs, gen=6)
    # every drafted token obeyed the budget: 1 + dl <= budget per slot
    assert spec.accepted_tok_per_tick >= 1.0


def test_engine_validates_draft_config(params, draft):
    with pytest.raises(ValueError, match="needs a DraftModel"):
        mk(params, spec_k=4)
    with pytest.raises(ValueError, match="speculate_k > 0"):
        mk(params, draft=draft)
    import dataclasses
    bad = dataclasses.replace(draft, cfg=dataclasses.replace(
        draft.cfg, vocab_size=CFG.vocab_size + 1))
    with pytest.raises(ValueError, match="vocab"):
        mk(params, spec_k=4, draft=bad)


def test_draft_model_is_materialized_small(params):
    # a low-keep circuit materializes at a genuinely smaller width (the
    # high-keep default may pad back to d_ff when some layer keeps every
    # block — layers share one stacked shape)
    half = HornConfig(enabled=True, keep_hidden=0.5, keep_input=1.0,
                      block_size=16)
    dm = ModelBank(CFG, half, 2, seed=0).draft_model(1, params)
    assert dm.cfg.d_ff < CFG.d_ff
    assert 0.0 < dm.kept_frac < 1.0
    assert dm.circuit == 1
