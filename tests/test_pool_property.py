"""Hypothesis property tests for the ref-counted COW PagePool: random
alloc / ensure / fork / prepare_write / publish / free / pressure-evict
interleavings must keep ``check_invariants`` green after every op —
refcounts equal table references, prefix-cache-held pages are unreferenced
by live sequences, no page is simultaneously free and mapped, and a
COW-prepared write range is always exclusively owned by the writer.
"""
import numpy as np
import pytest

from repro.serving import PagePool, PagePoolOOM, chain_hashes

P = 4  # page size

# ---------------------------------------------------------------------------
# hypothesis property test: random interleavings vs invariants
# ---------------------------------------------------------------------------
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_pool_random_interleavings_keep_invariants(data):
    """alloc / ensure / fork / prepare_write / publish / free / pressure-
    evict in random order: after every op the pool invariants hold —
    refcounts equal table references, cache-held pages are unreferenced,
    no page is both free and mapped, and a COW-prepared range is always
    exclusively owned (refcount 1) by the writer."""
    pool = PagePool(num_pages=data.draw(st.integers(6, 20), label="pages"),
                    page_size=P, prefix_cache=True)
    streams = {}                     # seq -> (tokens, hashes)
    next_seq = 0
    for _ in range(data.draw(st.integers(5, 30), label="ops")):
        live = sorted(streams)
        op = data.draw(st.sampled_from(
            ["alloc", "ensure", "fork", "write", "publish", "free",
             "truncate"]))
        try:
            if op == "alloc":
                n = data.draw(st.integers(1, 3 * P))
                toks = np.asarray(data.draw(st.lists(
                    st.integers(0, 2), min_size=n, max_size=n)), np.int32)
                hashes = chain_hashes(b"ns", toks, P)
                cached = pool.match_pages(hashes[:max(0, (n - 1) // P)])
                fresh = pool.pages_for(n) - len(cached)
                pool.alloc_pages(next_seq, fresh, owner=next_seq % 2,
                                 cached=cached)
                streams[next_seq] = (toks, hashes)
                next_seq += 1
            elif op == "ensure" and live:
                seq = data.draw(st.sampled_from(live))
                toks, _ = streams[seq]
                extra = data.draw(st.integers(1, P + 1))
                grown = np.concatenate(
                    [toks, np.zeros((extra,), np.int32)])
                pool.ensure(seq, len(grown))
                streams[seq] = (grown, chain_hashes(b"ns", grown, P))
            elif op == "fork" and live:
                src = data.draw(st.sampled_from(live))
                pool.fork(src, next_seq, owner=next_seq % 2)
                streams[next_seq] = streams[src]
                next_seq += 1
            elif op == "write" and live:
                seq = data.draw(st.sampled_from(live))
                table = pool.table(seq)
                if table:
                    hi = len(table) * P
                    a = data.draw(st.integers(0, hi - 1))
                    b = data.draw(st.integers(a + 1, hi))
                    pool.prepare_write(seq, a, b)
                    for i in range(a // P, pool.pages_for(b)):
                        page = pool.table(seq)[i]
                        assert pool.refcount(page) == 1, \
                            "COW left a written page shared"
            elif op == "publish" and live:
                seq = data.draw(st.sampled_from(live))
                toks, hashes = streams[seq]
                pool.publish_prefix(seq, hashes, len(hashes))
            elif op == "free" and live:
                seq = data.draw(st.sampled_from(live))
                pool.free_seq(seq)
                del streams[seq]
            elif op == "truncate" and live:
                # speculative partial-accept rollback: drop the tail
                seq = data.draw(st.sampled_from(live))
                toks, _ = streams[seq]
                keep = data.draw(st.integers(0, max(0, len(toks))))
                pool.truncate_seq(seq, keep,
                                  recredit=data.draw(st.booleans()))
                kept = toks[:pool.pages_for(keep) * P] if keep else toks[:0]
                streams[seq] = (kept, chain_hashes(b"ns", kept, P))
        except PagePoolOOM:
            pass                      # legal outcome under pressure
        pool.check_invariants()
    for seq in sorted(streams):
        pool.free_seq(seq)
    pool.check_invariants()
    assert pool.used_pages == 0


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_pool_cow_never_touches_shared_pages(data):
    """The issue's refcount invariant, stated directly: after
    ``prepare_write`` the written range is exclusively owned, and every
    page another sequence still maps kept its refcount and its bytes
    (same page id in the other table)."""
    pool = PagePool(num_pages=16, page_size=P, prefix_cache=True)
    n = data.draw(st.integers(1, 4)) * P
    pool.alloc(0, n)
    forks = data.draw(st.integers(1, 3))
    for f in range(1, forks + 1):
        pool.fork(0, f)
    before = {s: pool.table(s) for s in range(forks + 1)}
    writer = data.draw(st.integers(0, forks))
    a = data.draw(st.integers(0, n - 1))
    pool.prepare_write(writer, a, n)
    for s in range(forks + 1):
        if s == writer:
            continue
        assert pool.table(s) == before[s], "COW mutated a reader's table"
    for i in range(a // P, n // P):
        assert pool.refcount(pool.table(writer)[i]) == 1
    pool.check_invariants()


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fork_then_partial_rollback_releases_only_the_tail(data):
    """The speculative-decode lifecycle: fork a published prefix, COW the
    tail for draft writes, then roll a rejected tail back with
    ``truncate_seq`` — the reader's table is untouched, only tail pages
    are released, and under ``recredit`` the freed pages stay promised to
    the writer (its later re-grow can never lose them to a bystander)."""
    pool = PagePool(num_pages=16, page_size=P, prefix_cache=True)
    n_pages = data.draw(st.integers(2, 4), label="pages")
    n = n_pages * P
    toks = np.asarray(data.draw(st.lists(st.integers(0, 2),
                                         min_size=n, max_size=n)), np.int32)
    hashes = chain_hashes(b"ns", toks, P)
    pool.alloc(0, n)
    pool.publish_prefix(0, hashes, n_pages)
    pool.fork(0, 1)                       # the speculating sequence
    spec_end = n + data.draw(st.integers(1, 2 * P), label="drafted")
    pool.ensure(1, spec_end)              # draft tail pages
    pool.prepare_write(1, n - 1, spec_end)
    reader_before = pool.table(0)
    used_before = pool.used_pages
    keep = data.draw(st.integers(n, spec_end), label="accepted")
    recredit = data.draw(st.booleans(), label="recredit")
    released = pool.truncate_seq(1, keep, recredit=recredit)
    pool.check_invariants()
    assert pool.table(0) == reader_before, "rollback mutated the reader"
    assert released == pool.pages_for(spec_end) - pool.pages_for(keep)
    assert pool.used_pages == used_before - released
    if recredit:
        assert pool.deferred_pages == released
        # the promise is redeemable even after a bystander drains the
        # free list: the writer re-grows to where it was, OOM-free
        grabber = 2
        free_now = pool.free_pages - pool.deferred_pages
        if free_now:
            pool.alloc_pages(grabber, free_now)
        pool.ensure(1, spec_end)
        assert pool.deferred_pages == 0
        pool.check_invariants()


