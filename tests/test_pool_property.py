"""Hypothesis property tests for the ref-counted COW PagePool: random
alloc / ensure / fork / prepare_write / publish / free / pressure-evict
interleavings must keep ``check_invariants`` green after every op —
refcounts equal table references, prefix-cache-held pages are unreferenced
by live sequences, no page is simultaneously free and mapped, and a
COW-prepared write range is always exclusively owned by the writer.
"""
import numpy as np
import pytest

from repro.serving import PagePool, PagePoolOOM, chain_hashes

P = 4  # page size

# ---------------------------------------------------------------------------
# hypothesis property test: random interleavings vs invariants
# ---------------------------------------------------------------------------
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_pool_random_interleavings_keep_invariants(data):
    """alloc / ensure / fork / prepare_write / publish / free / pressure-
    evict in random order: after every op the pool invariants hold —
    refcounts equal table references, cache-held pages are unreferenced,
    no page is both free and mapped, and a COW-prepared range is always
    exclusively owned (refcount 1) by the writer."""
    pool = PagePool(num_pages=data.draw(st.integers(6, 20), label="pages"),
                    page_size=P, prefix_cache=True)
    streams = {}                     # seq -> (tokens, hashes)
    next_seq = 0
    for _ in range(data.draw(st.integers(5, 30), label="ops")):
        live = sorted(streams)
        op = data.draw(st.sampled_from(
            ["alloc", "ensure", "fork", "write", "publish", "free",
             "truncate"]))
        try:
            if op == "alloc":
                n = data.draw(st.integers(1, 3 * P))
                toks = np.asarray(data.draw(st.lists(
                    st.integers(0, 2), min_size=n, max_size=n)), np.int32)
                hashes = chain_hashes(b"ns", toks, P)
                cached = pool.match_pages(hashes[:max(0, (n - 1) // P)])
                fresh = pool.pages_for(n) - len(cached)
                pool.alloc_pages(next_seq, fresh, owner=next_seq % 2,
                                 cached=cached)
                streams[next_seq] = (toks, hashes)
                next_seq += 1
            elif op == "ensure" and live:
                seq = data.draw(st.sampled_from(live))
                toks, _ = streams[seq]
                extra = data.draw(st.integers(1, P + 1))
                grown = np.concatenate(
                    [toks, np.zeros((extra,), np.int32)])
                pool.ensure(seq, len(grown))
                streams[seq] = (grown, chain_hashes(b"ns", grown, P))
            elif op == "fork" and live:
                src = data.draw(st.sampled_from(live))
                pool.fork(src, next_seq, owner=next_seq % 2)
                streams[next_seq] = streams[src]
                next_seq += 1
            elif op == "write" and live:
                seq = data.draw(st.sampled_from(live))
                table = pool.table(seq)
                if table:
                    hi = len(table) * P
                    a = data.draw(st.integers(0, hi - 1))
                    b = data.draw(st.integers(a + 1, hi))
                    pool.prepare_write(seq, a, b)
                    for i in range(a // P, pool.pages_for(b)):
                        page = pool.table(seq)[i]
                        assert pool.refcount(page) == 1, \
                            "COW left a written page shared"
            elif op == "publish" and live:
                seq = data.draw(st.sampled_from(live))
                toks, hashes = streams[seq]
                pool.publish_prefix(seq, hashes, len(hashes))
            elif op == "free" and live:
                seq = data.draw(st.sampled_from(live))
                pool.free_seq(seq)
                del streams[seq]
            elif op == "truncate" and live:
                # speculative partial-accept rollback: drop the tail
                seq = data.draw(st.sampled_from(live))
                toks, _ = streams[seq]
                keep = data.draw(st.integers(0, max(0, len(toks))))
                pool.truncate_seq(seq, keep,
                                  recredit=data.draw(st.booleans()))
                kept = toks[:pool.pages_for(keep) * P] if keep else toks[:0]
                streams[seq] = (kept, chain_hashes(b"ns", kept, P))
        except PagePoolOOM:
            pass                      # legal outcome under pressure
        pool.check_invariants()
    for seq in sorted(streams):
        pool.free_seq(seq)
    pool.check_invariants()
    assert pool.used_pages == 0


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_pool_cow_never_touches_shared_pages(data):
    """The issue's refcount invariant, stated directly: after
    ``prepare_write`` the written range is exclusively owned, and every
    page another sequence still maps kept its refcount and its bytes
    (same page id in the other table)."""
    pool = PagePool(num_pages=16, page_size=P, prefix_cache=True)
    n = data.draw(st.integers(1, 4)) * P
    pool.alloc(0, n)
    forks = data.draw(st.integers(1, 3))
    for f in range(1, forks + 1):
        pool.fork(0, f)
    before = {s: pool.table(s) for s in range(forks + 1)}
    writer = data.draw(st.integers(0, forks))
    a = data.draw(st.integers(0, n - 1))
    pool.prepare_write(writer, a, n)
    for s in range(forks + 1):
        if s == writer:
            continue
        assert pool.table(s) == before[s], "COW mutated a reader's table"
    for i in range(a // P, n // P):
        assert pool.refcount(pool.table(writer)[i]) == 1
    pool.check_invariants()


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fork_then_partial_rollback_releases_only_the_tail(data):
    """The speculative-decode lifecycle: fork a published prefix, COW the
    tail for draft writes, then roll a rejected tail back with
    ``truncate_seq`` — the reader's table is untouched, only tail pages
    are released, and under ``recredit`` the freed pages stay promised to
    the writer (its later re-grow can never lose them to a bystander)."""
    pool = PagePool(num_pages=16, page_size=P, prefix_cache=True)
    n_pages = data.draw(st.integers(2, 4), label="pages")
    n = n_pages * P
    toks = np.asarray(data.draw(st.lists(st.integers(0, 2),
                                         min_size=n, max_size=n)), np.int32)
    hashes = chain_hashes(b"ns", toks, P)
    pool.alloc(0, n)
    pool.publish_prefix(0, hashes, n_pages)
    pool.fork(0, 1)                       # the speculating sequence
    spec_end = n + data.draw(st.integers(1, 2 * P), label="drafted")
    pool.ensure(1, spec_end)              # draft tail pages
    pool.prepare_write(1, n - 1, spec_end)
    reader_before = pool.table(0)
    used_before = pool.used_pages
    keep = data.draw(st.integers(n, spec_end), label="accepted")
    recredit = data.draw(st.booleans(), label="recredit")
    released = pool.truncate_seq(1, keep, recredit=recredit)
    pool.check_invariants()
    assert pool.table(0) == reader_before, "rollback mutated the reader"
    assert released == pool.pages_for(spec_end) - pool.pages_for(keep)
    assert pool.used_pages == used_before - released
    if recredit:
        assert pool.deferred_pages == released
        # the promise is redeemable even after a bystander drains the
        # free list: the writer re-grows to where it was, OOM-free
        grabber = 2
        free_now = pool.free_pages - pool.deferred_pages
        if free_now:
            pool.alloc_pages(grabber, free_now)
        pool.ensure(1, spec_end)
        assert pool.deferred_pages == 0
        pool.check_invariants()



# ---------------------------------------------------------------------------
# int8 paged-KV properties: quantization roundtrip bound, and scale rows
# traveling with their pages through COW / fork / truncate page copies
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.data())
def test_int8_roundtrip_error_within_quantization_step(data):
    """quantize_int8(axis=(1, 3)) -> dequantize: every element of a
    [P, psize, KH, D] pool must come back within its (page, head)'s
    quantization step, amax / 127 (symmetric rounding: half a step plus
    float slop; one full step is a safe outer bound)."""
    from repro.optim.compression import dequantize_int8, quantize_int8

    P_, psize, KH, D = (data.draw(st.integers(1, 4), label="P"),
                        data.draw(st.sampled_from([2, 4]), label="psize"),
                        data.draw(st.integers(1, 3), label="KH"),
                        data.draw(st.sampled_from([4, 8]), label="D"))
    scale_mag = data.draw(st.sampled_from([1e-3, 1.0, 100.0]), label="mag")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31), label="s"))
    x = np.asarray(rng.normal(size=(P_, psize, KH, D)) * scale_mag,
                   np.float32)
    q, sc = quantize_int8(x, axis=(1, 3))
    back = np.asarray(dequantize_int8(q, sc))
    step = np.abs(x).max(axis=(1, 3), keepdims=True) / 127.0
    assert (np.abs(back - x) <= step + 1e-9).all()
    assert np.asarray(q).dtype == np.int8
    assert sc.shape == (P_, 1, KH, 1)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_page_copy_moves_scales_with_pages(data):
    """The device-side COW page copy on an int8 cache: after copying
    src[i] -> dst[i], the *dequantized* dst page equals the dequantized
    src page — i.e. the scale sidecar rows traveled with their pages
    (fork / prefix-cache publish / preemption restore never split a page
    from its scale).  Checked for both the plain [P, ...] leaf layout and
    the scanned [R, P, ...] superblock layout."""
    import jax.numpy as jnp
    from repro.core.steps import make_page_copy_step
    from repro.optim.compression import quantize_int8

    psize, KH, D, NP = 4, 2, 4, 8
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31), label="s"))
    scanned = data.draw(st.booleans(), label="scanned")
    shape = (2, NP, psize, KH, D) if scanned else (NP, psize, KH, D)
    x = np.asarray(rng.normal(size=shape), np.float32)
    ax = (2, 4) if scanned else (1, 3)
    q, sc = quantize_int8(jnp.asarray(x), axis=ax)
    sc = sc[:, :, 0, :, 0] if scanned else sc[:, 0, :, 0]
    n = data.draw(st.integers(1, 4), label="copies")
    src = data.draw(st.lists(st.integers(1, NP - 1), min_size=n, max_size=n),
                    label="src")
    # distinct dst pages (a page is only ever COW-copied onto a free page)
    dst = data.draw(st.permutations(list(range(1, NP))), label="dst")[:n]

    def deq(pool, scale):
        pool, scale = np.asarray(pool, np.float32), np.asarray(scale)
        if scanned:
            return pool * scale[:, :, None, :, None]
        return pool * scale[:, None, :, None]

    before = deq(q, sc)              # snapshot first: the copy donates its
    copy = make_page_copy_step()     # cache argument (in-place on device)
    (q2, sc2), = copy([(q, sc)], jnp.asarray(src, jnp.int32),
                      jnp.asarray(dst, jnp.int32))
    after = deq(q2, sc2)
    want = before.copy()
    for s_, d_ in zip(src, dst):            # later copies win, like x.at[]
        if scanned:
            want[:, d_] = before[:, s_]
        else:
            want[d_] = before[s_]
    untouched = [p for p in range(NP) if p not in dst]
    sel = (slice(None),) if scanned else ()
    for p in untouched:
        assert np.array_equal(after[sel + (p,)], want[sel + (p,)])
    for s_, d_ in zip(src, dst):
        assert np.array_equal(after[sel + (d_,)], want[sel + (d_,)]), \
            "scale row did not travel with its page"


def test_pool_fork_and_truncate_preserve_scale_correspondence():
    """Host-side lifecycle: PagePool fork shares page *ids* (scales are
    indexed by page id, so correspondence is automatic), COW prepare_write
    gives the writer fresh ids — and the engine copies pool+scale rows to
    the new ids together (test above) — and truncate_seq only drops tail
    ids, never remapping survivors."""
    pool = PagePool(num_pages=16, page_size=P, prefix_cache=True)
    pool.alloc(0, 3 * P)
    t0 = pool.table(0)
    pool.fork(0, 1)
    assert pool.table(1) == t0              # shared ids -> shared scales
    pool.prepare_write(1, P, 3 * P)         # COW the tail
    t1 = pool.table(1)
    assert t1[0] == t0[0]                   # untouched head still shared
    assert t1[1] != t0[1] and t1[2] != t0[2]
    pool.truncate_seq(1, 2 * P)
    assert pool.table(1) == t1[:2]          # survivors keep their ids
    pool.check_invariants()
