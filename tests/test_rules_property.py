"""Property test: sharding rules are valid for EVERY (arch x shape x mesh
factorization) — the elastic-scaling guarantee that a resized cluster never
produces an invalid sharding, only degraded (replicated) ones."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import SHAPES, get_model_config, list_archs
from repro.launch.mesh import sharding_rules

ARCHS = [a for a in list_archs() if a != "horn-mnist"]


class _FakeMesh:
    def __init__(self, data, model, pod=None):
        sizes = {"data": data, "model": model}
        if pod:
            sizes = {"pod": pod, **sizes}
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


def _dims(cfg, shape):
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "heads": cfg.num_heads, "kv_heads": cfg.num_kv_heads,
        "head_dim": cfg.head_dim, "kv_head_dim": cfg.head_dim,
        "ffn": cfg.d_ff, "act_ffn": cfg.d_ff, "moe_ffn": cfg.moe_ff,
        "embed": cfg.d_model, "vocab": cfg.vocab_size,
        "experts": cfg.num_experts,
        "ssm_inner": d_in, "ssm_heads": d_in // cfg.ssm_head_dim,
        "kv_seq": shape.seq_len, "sp_seq": shape.seq_len,
        "seq": shape.seq_len,
    }


@given(arch=st.sampled_from(ARCHS),
       shape_name=st.sampled_from(list(SHAPES)),
       data=st.sampled_from([1, 2, 4, 8, 12, 14, 16]),
       model=st.sampled_from([1, 2, 4, 8, 12, 16]),
       pod=st.sampled_from([None, 2, 3]))
@settings(max_examples=120, deadline=None)
def test_rules_always_divisible(arch, shape_name, data, model, pod):
    cfg = get_model_config(arch)
    shape = SHAPES[shape_name]
    mesh = _FakeMesh(data, model, pod)
    rules = sharding_rules(cfg, mesh, shape)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dims = _dims(cfg, shape)
    for axis, mapped in rules.items():
        if mapped is None or axis not in dims or dims[axis] <= 0:
            continue
        for m in (mapped if isinstance(mapped, tuple) else (mapped,)):
            assert dims[axis] % sizes[m] == 0, \
                (arch, shape_name, axis, dims[axis], m, sizes[m])
    # batch rule: either divisible or dropped
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if rules["batch"] is not None and dp > 1:
        covered = 1
        for m in rules["batch"]:
            covered *= sizes[m]
        assert shape.global_batch % covered == 0
