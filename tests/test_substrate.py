"""Substrate tests: data determinism, optimizers, neuron-centric engine,
MNIST trainer wiring, topology validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import HornConfig, TopologyConfig
from repro.core.neuron_centric import (NeuronNetwork, divide_by_sum_interlayer,
                                       paper_mnist_network,
                                       softmax_interlayer)
from repro.core.parallel_dropout import HornState
from repro.data.pipeline import (MnistBatcher, SyntheticTokenPipeline,
                                 TokenPipelineConfig)
from repro.optim.sgd import adamw_init, adamw_update, sgdm_init, sgdm_update


# ---------------------------------------------------------------------------
# data pipeline: the fault-tolerance determinism contract
# ---------------------------------------------------------------------------
def test_token_pipeline_deterministic_by_step():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=32, global_batch=4)
    p1, p2 = SyntheticTokenPipeline(cfg), SyntheticTokenPipeline(cfg)
    for step in (0, 7, 1234):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_token_pipeline_host_slicing_partitions_batch():
    cfg = TokenPipelineConfig(vocab_size=100, seq_len=8, global_batch=8,
                              num_hosts=4, host_id=2)
    pipe = SyntheticTokenPipeline(cfg)
    full = pipe.batch_at(3)["tokens"]
    mine = pipe.host_slice(3)["tokens"]
    np.testing.assert_array_equal(mine, full[4:6])


def test_labels_are_next_tokens():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=2)
    b = SyntheticTokenPipeline(cfg).batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)


def test_mnist_batcher_group_split():
    x = np.arange(200, dtype=np.float32).reshape(100, 2)
    y = np.arange(100, dtype=np.int32)
    b = MnistBatcher(x, y, batch=20).group_batch_at(0, num_groups=4)
    assert b["x"].shape == (4, 5, 2)
    assert b["y"].shape == (4, 5)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def test_sgdm_matches_manual():
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st_ = sgdm_init(p)
    p2, st2 = sgdm_update(g, st_, p, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.95, 2.05])
    p3, _ = sgdm_update(g, st2, p2, lr=0.1, momentum=0.9)
    # v = 0.9*0.5 + 0.5 = 0.95 -> w = 0.95 - 0.095
    np.testing.assert_allclose(np.asarray(p3["w"]), [0.855, 2.145], rtol=1e-6)


def test_adamw_step_direction():
    p = {"w": jnp.zeros(3)}
    g = {"w": jnp.asarray([1.0, -1.0, 0.0])}
    st_ = adamw_init(p)
    p2, st2 = adamw_update(g, st_, p, lr=0.1)
    out = np.asarray(p2["w"])
    assert out[0] < 0 and out[1] > 0 and out[2] == 0
    assert int(st2["t"]) == 1


@given(lr=st.floats(1e-4, 1e-1), mu=st.floats(0.0, 0.99),
       seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_sgdm_descends_quadratic(lr, mu, seed):
    """Momentum SGD reduces a convex quadratic (paper's optimizer sanity)."""
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=4), jnp.float32)
    p = {"w": jnp.zeros(4)}
    opt = sgdm_init(p)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = float(loss(p))
    for _ in range(50):
        g = jax.grad(loss)(p)
        p, opt = sgdm_update(g, opt, p, lr=lr * (1 - mu), momentum=mu)
    assert float(loss(p)) < l0


# ---------------------------------------------------------------------------
# neuron-centric engine
# ---------------------------------------------------------------------------
def test_interlayer_normalization():
    """Paper's interlayer() example normalizes positive (ReLU) activations."""
    nn = NeuronNetwork(input_units=4)
    nn.add_layer(8, "relu", interlayer=divide_by_sum_interlayer)
    params = nn.init(jax.random.key(3))
    out = np.asarray(nn.apply(params, jnp.abs(
        jax.random.normal(jax.random.key(1), (2, 4)))))
    np.testing.assert_allclose(out.sum(-1), [1.0, 1.0], atol=1e-5)
    assert (out >= 0).all()

    nn2 = NeuronNetwork(input_units=4)
    nn2.add_layer(4, "identity", interlayer=softmax_interlayer)
    p2 = nn2.init(jax.random.key(0))
    out2 = np.asarray(nn2.apply(p2, jnp.ones((2, 4))))
    np.testing.assert_allclose(out2.sum(-1), [1.0, 1.0], atol=1e-5)


def test_dropout_neuron_masks_only_in_training():
    nn = paper_mnist_network(hidden=32, depth=1)
    params = nn.init(jax.random.key(0))
    x = jnp.ones((4, 784))
    eval_out = nn.apply(params, x, horn=None)
    np.testing.assert_array_equal(np.asarray(eval_out),
                                  np.asarray(nn.apply(params, x, horn=None)))
    horn = HornState(key=jax.random.key(1),
                     cfg=HornConfig(enabled=True, block_size=1), num_groups=2)
    train_out = nn.apply(params, x, horn=horn)
    assert not np.array_equal(np.asarray(eval_out), np.asarray(train_out))


def test_mnist_parallel_beats_chance_quickly():
    from repro.core.collective_trainer import train_mnist
    res = train_mnist(num_groups=4, batch_per_group=16, num_steps=200,
                      eval_every=200, n_train=2000, hidden=64, lr=0.005)
    assert res.final_accuracy > 0.3, res.final_accuracy


def test_topology_validation():
    from repro.core.topology import describe, validate
    t = validate(TopologyConfig(kind="local_sgd", local_sgd_period=8,
                                grad_compression="int8"))
    assert "H=8" in describe(t) and "int8" in describe(t)
    with pytest.raises(AssertionError):
        validate(TopologyConfig(kind="gossip"))
