"""Elastic remesh, multi-device compressed merges, and HLO cost-model
regression (locks the §Roofline instrument against known-FLOPs programs)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest


# ---------------------------------------------------------------------------
# HLO cost model — known-truth regressions
# ---------------------------------------------------------------------------
def test_hlocost_counts_scan_trip_counts():
    from repro.launch.analysis import HloCost

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = jax.jit(f).lower(jnp.zeros((128, 128), jnp.float32)).compile()
    cm = HloCost(c.as_text())
    expected = 10 * 2 * 128 ** 3
    assert abs(cm.flops - expected) / expected < 0.01
    # XLA's own cost_analysis undercounts by the trip count (the reason the
    # custom model exists) — guard that assumption too
    ca = c.cost_analysis()
    if isinstance(ca, list):     # older jax returns one dict per device
        ca = ca[0]
    raw = ca.get("flops", 0.0)
    assert raw < expected / 5


def test_hlocost_nested_scan_multiplies():
    from repro.launch.analysis import HloCost

    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = jax.jit(f).lower(jnp.zeros((64, 64), jnp.float32)).compile()
    cm = HloCost(c.as_text())
    expected = 4 * 3 * 2 * 64 ** 3
    assert abs(cm.flops - expected) / expected < 0.02


def test_collective_parse_shapes():
    from repro.launch.analysis import shape_bytes
    assert shape_bytes("bf16[2,128]{1,0}") == 2 * 128 * 2
    assert shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert shape_bytes("pred[8]") == 8


def test_dryrun_applicability_matrix():
    from repro.launch.dryrun import applicable
    ok, _ = applicable("mamba2-2.7b", "long_500k")
    assert ok
    ok, why = applicable("qwen3-1.7b", "long_500k")
    assert not ok and "full-attention" in why
    ok, why = applicable("whisper-base", "long_500k")
    assert not ok


# ---------------------------------------------------------------------------
# Elastic remesh
# ---------------------------------------------------------------------------
def test_valid_meshes_after_node_loss():
    from repro.runtime.elastic import valid_meshes
    # 256 chips minus one 8-chip host = 248 -> (data, model) options
    opts = valid_meshes(248)
    assert (248, 1) in opts and (124, 2) in opts and (31, 8) in opts
    assert all(d * m == 248 for d, m in opts)


ELASTIC_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs.base import (HornConfig, RunConfig, ShapeConfig,
                                    get_model_config, reduced)
    from repro.core import steps as S
    from repro.launch.mesh import ShardingCtx, sharding_rules
    from repro.runtime.elastic import remesh_state
    from repro.checkpoint.checkpointer import Checkpointer
    import tempfile

    cfg = reduced(get_model_config("qwen3-1.7b"), d_model=64, d_ff=128)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 8),
                    horn=HornConfig(enabled=False), learning_rate=1e-2)

    # train 2 steps on a 4x2 mesh
    devs = np.array(jax.devices()).reshape(4, 2)
    mesh1 = Mesh(devs, ("data", "model"))
    step1, sh1 = S.make_train_step(run, mesh1)
    state = jax.jit(lambda k: S.init_state(k, run),
                    out_shardings=sh1["state"])(jax.random.key(0))
    batch = {"tokens": jnp.ones((8, 32), jnp.int32),
             "labels": jnp.ones((8, 32), jnp.int32)}
    state, m1 = step1(state, batch)
    state, m1 = step1(state, batch)

    # checkpoint, "lose" devices -> restore onto a 2x2 mesh and keep training
    ckdir = tempfile.mkdtemp()
    ck = Checkpointer(ckdir)
    ck.save(2, state)

    devs2 = np.array(jax.devices()[:4]).reshape(2, 2)
    mesh2 = Mesh(devs2, ("data", "model"))
    step2, sh2 = S.make_train_step(run, mesh2)
    like = jax.eval_shape(lambda: S.init_state(jax.random.key(0), run))
    restored, at = ck.restore(like, shardings=sh2["state"])
    assert at == 2
    batch2 = {"tokens": jnp.ones((8, 32), jnp.int32),
              "labels": jnp.ones((8, 32), jnp.int32)}
    restored, m2 = step2(restored, batch2)
    assert np.isfinite(float(m2["loss"]))
    assert int(np.asarray(restored["step"])) == 3
    print("ELASTIC_OK", float(m2["loss"]))
""")


def test_elastic_restart_on_smaller_mesh():
    """Full elastic cycle: train on 4x2 -> checkpoint -> restore on 2x2 ->
    continue training.  Runs in a subprocess with 8 forced host devices."""
    import os
    r = subprocess.run([sys.executable, "-c", ELASTIC_PROG],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "ELASTIC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


MERGE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.configs.base import TopologyConfig
    from repro.core.group_sync import merge_grads

    mesh = Mesh(np.array(jax.devices()), ("data",))
    topo = TopologyConfig(kind="allreduce", grad_compression="int8")
    g_global = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8) / 37.0

    def run(g):
        merged, _ = merge_grads({"w": g}, "data", topo, residuals=None)
        return merged["w"]

    from repro.launch.mesh import shard_map
    fn = shard_map(run, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                   check_vma=False)
    out = np.asarray(fn(g_global))
    want = np.broadcast_to(np.asarray(g_global).mean(0), (4, 8))
    err = np.abs(out - want).max()
    assert err < np.abs(want).max() / 100, (err, out[0], want[0])
    print("MERGE_OK", err)
""")


def test_compressed_merge_multidevice():
    """int8 error-feedback merge across 4 real (host) devices ~ exact mean."""
    import os
    r = subprocess.run([sys.executable, "-c", MERGE_PROG],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "MERGE_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
