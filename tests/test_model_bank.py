"""Multi-submodel serving tests: ModelBank mask/materialize parity, Router
policies, per-owner pool accounting, routed decode byte-identical to a
dedicated one-model engine (with >= 2 sub-models co-batched in one jitted
tick), on-device ensemble combine vs a dense per-circuit reference, and the
incremental block-table row sync.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import HornConfig, get_model_config, reduced
from repro.core.steps import make_ctx
from repro.models import api
from repro.models import transformer as T
from repro.serving import (Engine, EngineConfig, ModelBank, PagePool, Router)

HORN = HornConfig(enabled=True, keep_hidden=0.5, keep_input=1.0,
                  block_size=16)


def _cfg(**over):
    # float32 end to end so masked-parent vs materialized / paged-vs-dense
    # comparisons are exact-or-tight despite different reduction shapes
    return reduced(get_model_config("qwen3-1.7b"), dtype="float32", **over)


def _params(cfg):
    return api.model_init(jax.random.key(0), cfg)


def _serve_masks_for(bank, ids):
    """Host-side gather of per-slot masks (what the unified step does on
    device) for dense-reference forwards."""
    ids = np.asarray(ids)
    return {k: jnp.asarray(v[ids]) for k, v in bank.masks.items()}


# ---------------------------------------------------------------------------
# bank construction
# ---------------------------------------------------------------------------
def test_bank_masks_shapes_determinism_and_liveness():
    cfg = _cfg()
    bank = ModelBank(cfg, HORN, 4, seed=3)
    assert set(bank.masks) == {"ffn"}            # keep_input=1 -> no input mask
    m = bank.masks["ffn"]
    assert m.shape == (4, cfg.num_layers, cfg.d_ff)
    assert set(np.unique(m)) <= {0.0, 1.0}
    # every circuit keeps >= 1 live block in every layer (stays connected)
    assert (m.sum(-1) > 0).all()
    # circuits are distinct and the draw is deterministic in the seed
    assert any(not np.array_equal(m[0], m[g]) for g in range(1, 4))
    again = ModelBank(cfg, HORN, 4, seed=3)
    assert np.array_equal(m, again.masks["ffn"])
    assert not np.array_equal(m, ModelBank(cfg, HORN, 4, seed=4).masks["ffn"])
    # subset re-indexes rows without redrawing
    sub = bank.subset([2])
    assert sub.num_submodels == 1
    assert np.array_equal(sub.masks["ffn"][0], m[2])
    fr = bank.kept_fractions()["ffn"]
    assert len(fr) == 4 and all(0 < f <= 1 for f in fr)


def test_bank_input_and_head_masks_when_configured():
    cfg = _cfg()
    horn = HornConfig(enabled=True, keep_hidden=0.5, keep_input=0.75,
                      block_size=16, mask_attention_heads=True)
    bank = ModelBank(cfg, horn, 3)
    assert set(bank.masks) == {"ffn", "input", "heads"}
    assert bank.masks["input"].shape == (3, cfg.d_model)
    assert bank.masks["heads"].shape == (3, cfg.num_layers, cfg.num_heads)
    assert (bank.masks["heads"].sum(-1) > 0).all()


def test_bank_rejects_ssm_arch():
    cfg = reduced(get_model_config("mamba2-2.7b"))
    with pytest.raises(ValueError, match="attention"):
        ModelBank(cfg, HORN, 2)


# ---------------------------------------------------------------------------
# materialize: small weights == masked parent (the paper's memory claim)
# ---------------------------------------------------------------------------
def test_materialize_matches_masked_parent_logits():
    cfg = _cfg()
    params = _params(cfg)
    bank = ModelBank(cfg, HORN, 2, seed=1)
    ctx = make_ctx(cfg, None)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 12)), jnp.int32)
    for g in range(2):
        small_cfg, small_params = bank.materialize(g, params)
        assert small_cfg.d_ff < cfg.d_ff          # physically smaller
        masks = _serve_masks_for(bank, [g, g])
        want, _, _ = api.prefill(params, {"tokens": tokens}, cfg, ctx,
                                 serve_masks=masks)
        got, _, _ = api.prefill(small_params, {"tokens": tokens}, small_cfg,
                                make_ctx(small_cfg, None))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)


def test_materialize_rejects_non_ffn_masks():
    cfg = _cfg()
    horn = HornConfig(enabled=True, keep_hidden=0.5, keep_input=0.75,
                      block_size=16)
    bank = ModelBank(cfg, horn, 2)
    with pytest.raises(ValueError, match="FFN-only"):
        bank.materialize(0, _params(cfg))


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
def test_router_least_loaded_balances_and_releases():
    r = Router(3, policy="least_loaded")
    assert [r.route() for _ in range(3)] == [0, 1, 2]
    r.release(1)
    assert r.route() == 1                        # refills the gap
    assert r.loads == [1, 1, 1]
    with pytest.raises(ValueError):
        r.release(2)
        r.release(2)                             # more releases than routes


def test_router_hash_affinity_is_stable():
    r = Router(4, policy="hash")
    a = r.route(session="user-a")
    assert all(r.route(session="user-a") == a for _ in range(5))
    p = np.asarray([5, 6, 7], np.int32)
    g = r.route(prompt=p)
    assert r.route(prompt=p.copy()) == g         # prompt-bytes fallback
    with pytest.raises(ValueError):
        r.route()                                # nothing to hash


def test_router_explicit_and_validation():
    r = Router(2, policy="explicit")
    assert r.route(submodel_id=1) == 1
    with pytest.raises(ValueError):
        r.route()                                # explicit needs an id
    with pytest.raises(ValueError):
        r.route(submodel_id=7)
    # explicit id overrides any policy
    assert Router(4, policy="least_loaded").route(submodel_id=3) == 3


# ---------------------------------------------------------------------------
# pool owner accounting
# ---------------------------------------------------------------------------
def test_pool_utilization_by_owner():
    pool = PagePool(num_pages=9, page_size=4)
    pool.alloc_pages(0, 3, owner=0)
    pool.alloc_pages(1, 2, owner=1)
    pool.alloc_pages(2, 1, owner=0)
    by = pool.utilization_by_owner()
    assert by[0] == 4 / 8 and by[1] == 2 / 8
    # integer page counts per owner, divided once: the documented equality
    # holds EXACTLY, not approximately (no per-sequence float accumulation)
    assert pool.pages_by_owner() == {0: 4, 1: 2}
    assert sum(pool.pages_by_owner().values()) == pool.used_pages
    assert sum(by.values()) == pool.utilization()
    pool.check_invariants()
    pool.free_seq(0)
    pool.free_seq(2)
    assert 0 not in pool.utilization_by_owner()
    pool.check_invariants()


def test_pool_utilization_by_owner_exact_on_awkward_capacity():
    # capacity 7 makes 1/7-steps inexact in binary floating point: the old
    # implementation accumulated one float fraction PER SEQUENCE, so seven
    # single-page sequences of one owner summed to 0.9999999999999998, not
    # utilization() == 1.0.  Integer page counts divided once per owner
    # give exactly 7/7.
    pool = PagePool(num_pages=8, page_size=4)
    for seq in range(7):
        pool.alloc_pages(seq, 1, owner="tenant")
    by = pool.utilization_by_owner()
    assert by == {"tenant": 1.0}
    assert sum(by.values()) == pool.utilization() == 1.0
    assert sum(pool.pages_by_owner().values()) == pool.used_pages == 7
    pool.check_invariants()


def test_pool_shared_page_attributed_once():
    """A page mapped by several owners counts once — for the owner of the
    earliest-registered sequence — so per-owner counts still sum exactly
    to used_pages under fork/adopt sharing."""
    pool = PagePool(num_pages=9, page_size=4, prefix_cache=True)
    pool.alloc_pages(0, 2, owner=0)
    pool.fork(0, 1, owner=1)                     # shares both pages
    pool.alloc_pages(2, 1, owner=1)
    assert pool.pages_by_owner() == {0: 2, 1: 1}
    assert sum(pool.pages_by_owner().values()) == pool.used_pages == 3
    assert sum(pool.utilization_by_owner().values()) == pool.utilization()
    pool.check_invariants()


# ---------------------------------------------------------------------------
# routed decode == dedicated engine; co-batching in one jitted tick
# ---------------------------------------------------------------------------
def _engine(cfg, params, bank, *, slots=2, temperature=0.0, router=None,
            pages=64):
    return Engine(cfg, params,
                  EngineConfig(num_slots=slots, num_pages=pages, page_size=8,
                               max_prompt_len=16, max_new_tokens=5,
                               token_budget=16, temperature=temperature,
                               policy="on_demand", kv_dtype="float32",
                               compute_dtype="float32"),
                  bank=bank, router=router)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_routed_decode_byte_identical_to_dedicated_engine(temperature):
    """The acceptance bar: a request routed through the multi-submodel
    engine (co-batched with another circuit's request in the SAME jitted
    ticks) emits exactly the tokens a dedicated one-model engine produces
    for that circuit — greedy and sampled."""
    cfg = _cfg()
    params = _params(cfg)
    bank = ModelBank(cfg, HORN, 2, seed=1)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (6, 9)]

    multi = _engine(cfg, params, bank, temperature=temperature,
                    router=Router(2, policy="explicit"))
    reqs = [multi.submit(p, 5, submodel_id=g)
            for g, p in enumerate(prompts)]
    multi.run(clock=iter(np.arange(1e6)).__next__)
    got = {r.submodel_id: list(r.out_tokens) for r in reqs}
    assert multi.ticks_cobatched >= 1            # >=2 circuits in one tick
    assert multi.cobatch_ratio > 0
    assert set(multi.tokens_by_submodel) == {0, 1}
    assert multi.peak_util_by_submodel.keys() == {0, 1}
    multi.pool.check_invariants()
    assert multi.pool.used_pages == 0

    for g, p in enumerate(prompts):
        ded = _engine(cfg, params, bank.subset([g]), temperature=temperature,
                      router=Router(1, policy="explicit"))
        ded._next_id = reqs[g].id                # same (request, step) keys
        r = ded.submit(p, 5, submodel_id=0)
        ded.run(clock=iter(np.arange(1e6)).__next__)
        assert list(r.out_tokens) == got[g], \
            f"submodel {g} diverged: {r.out_tokens} != {got[g]}"


def test_single_tenant_engine_unaffected_by_bank_plumbing():
    """No bank -> the engine must not require (or accept) routing args."""
    cfg = _cfg()
    eng = _engine(cfg, _params(cfg), None)
    with pytest.raises(ValueError, match="ModelBank"):
        eng.submit(np.asarray([1, 2], np.int32), 2, submodel_id=1)
    with pytest.raises(ValueError, match="ModelBank"):
        eng.submit(np.asarray([1, 2], np.int32), 2, ensemble="mean_logit")
    with pytest.raises(ValueError, match="ModelBank"):
        Engine(cfg, None, EngineConfig(), router=Router(2))


# ---------------------------------------------------------------------------
# ensemble: on-device combine vs dense per-circuit reference
# ---------------------------------------------------------------------------
def _dense_reference_ensemble(cfg, params, bank, prompt, max_new, combine):
    """Host-side oracle for the ensemble's shared-context semantics: the
    prompt context [0, L - 1) is encoded ONCE by the dense parent (no
    circuit masks — attention K/V is member-invariant by construction, the
    fact the engine's fork/prefix-cache path banks on); each circuit then
    encodes the last prompt token and its decode tail through its own
    masked FFNs.  Per-step logits are combined (mean-logit argmax, or
    majority vote over member argmaxes; ties -> lowest token id) and the
    combined token is fed back to every circuit."""
    ctx = make_ctx(cfg, None)
    G = bank.num_submodels
    L = len(prompt)
    buf = T.init_cache(cfg, 1, L + max_new, dtype=jnp.float32)
    if L > 1:
        _, shared, _ = api.prefill(
            params, {"tokens": jnp.asarray([prompt[:-1]], jnp.int32)}, cfg,
            ctx, serve_masks=None)

        def splice(b, p):
            ax = b.ndim - 3
            pad = [(0, 0)] * b.ndim
            pad[ax] = (0, b.shape[ax] - p.shape[ax])
            return jnp.pad(p, pad).astype(b.dtype)

        shared = jax.tree.map(splice, buf, shared)
    else:
        shared = buf
    caches = [shared for _ in range(G)]          # value-identical contexts

    def pick(step_logits):
        if combine == "mean_logit":
            return int(np.argmax(np.mean(step_logits, axis=0)))
        votes = np.bincount([int(np.argmax(l)) for l in step_logits],
                            minlength=cfg.vocab_size)
        return int(np.argmax(votes))

    toks = []
    feed = int(prompt[-1])                       # members encode this token
    for i in range(max_new):
        step_logits = []
        for g in range(G):
            lg, caches[g] = api.decode_step(
                params, caches[g], jnp.asarray([[feed]], jnp.int32),
                jnp.asarray(L - 1 + i, jnp.int32), cfg, ctx,
                serve_masks=_serve_masks_for(bank, [g]))
            step_logits.append(np.asarray(lg[0], np.float32))
        toks.append(pick(step_logits))
        feed = toks[-1]
    return toks


@pytest.mark.parametrize("combine", ["mean_logit", "majority_vote"])
def test_ensemble_matches_dense_reference(combine):
    cfg = _cfg()
    params = _params(cfg)
    bank = ModelBank(cfg, HORN, 3, seed=2)
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab_size, (7,)).astype(np.int32)
    max_new = 4
    want = _dense_reference_ensemble(cfg, params, bank,
                                     list(map(int, prompt)), max_new, combine)

    eng = _engine(cfg, params, bank, slots=3)
    group = eng.submit(prompt, max_new, ensemble=combine)
    eng.run(clock=iter(np.arange(1e6)).__next__)
    # every member carries the SAME combined stream
    for m in group.members:
        assert list(m.out_tokens) == want, \
            f"{combine}: {m.out_tokens} != {want}"
    assert group.finished
    eng.pool.check_invariants()
    assert eng.pool.used_pages == 0


def test_ensemble_group_survives_preemption_with_solo_traffic():
    """An ensemble group and a solo request squeezed into a tight pool:
    the group preempts/readmits as one unit and everything drains."""
    cfg = _cfg()
    params = _params(cfg)
    bank = ModelBank(cfg, HORN, 2, seed=1)
    eng = Engine(cfg, params,
                 EngineConfig(num_slots=3, num_pages=8, page_size=4,
                              max_prompt_len=8, max_new_tokens=6,
                              token_budget=12, policy="on_demand",
                              kv_dtype="float32", compute_dtype="float32"),
                 bank=bank)
    roomy = Engine(cfg, params,
                   EngineConfig(num_slots=3, num_pages=64, page_size=4,
                                max_prompt_len=8, max_new_tokens=6,
                                token_budget=12, policy="on_demand",
                                kv_dtype="float32", compute_dtype="float32"),
                   bank=bank)
    prompt = np.arange(1, 7, dtype=np.int32)
    solo_p = np.arange(1, 8, dtype=np.int32)
    outs = {}
    for e in (eng, roomy):
        # solo first -> the GROUP is the youngest unit and the preemption
        # victim; it must evict and re-admit as one lockstep unit
        solo = e.submit(solo_p, 6)
        g = e.submit(prompt, 6, ensemble="mean_logit")
        e.run(clock=iter(np.arange(1e6)).__next__)
        outs[e] = (list(g.out_tokens), list(solo.out_tokens))
        assert len({tuple(m.out_tokens) for m in g.members}) == 1
        e.pool.check_invariants()
        assert e.pool.used_pages == 0
    assert eng.preemptions >= 1, "pool was never squeezed"
    assert outs[eng] == outs[roomy], "preemption changed ensemble output"


# ---------------------------------------------------------------------------
# incremental block-table sync
# ---------------------------------------------------------------------------
def test_block_table_sync_is_incremental():
    """Steady decode inside one page must re-upload ZERO block-table rows;
    only admissions / page-boundary growth / vacating slots sync."""
    cfg = _cfg()
    eng = Engine(cfg, _params(cfg),
                 EngineConfig(num_slots=2, num_pages=8, page_size=16,
                              max_prompt_len=16, max_new_tokens=8,
                              token_budget=16, policy="reserve",
                              kv_dtype="float32", compute_dtype="float32"))
    eng.submit(np.arange(1, 5, dtype=np.int32), 8)   # 4+8 tokens -> 1 page
    eng.run(clock=iter(np.arange(1e6)).__next__)
    assert eng.steps >= 8
    # one row synced at admission; decode never crosses the page boundary
    assert eng.bt_rows_synced == 1
    # a second request re-uses the slot -> its row syncs once more
    eng.submit(np.arange(1, 5, dtype=np.int32), 8)
    eng.run(clock=iter(np.arange(1e6)).__next__)
    assert eng.bt_rows_synced == 2
