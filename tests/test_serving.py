"""Serving-path integration: token-by-token decode must reproduce the
prefill (teacher-forced) logits — validates KV/SSM cache math end-to-end,
including the flash-decode attention rewrite and shard_map cache updates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_model_config, reduced
from repro.core.steps import make_ctx
from repro.models import api
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b", "gemma2-27b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_teacher_forcing(arch):
    # capacity_factor high enough that no token is capacity-dropped: capacity
    # MoE drops late tokens under teacher forcing but never in one-token
    # decode — an inherent (documented) train/serve asymmetry, not a bug.
    cfg = reduced(get_model_config(arch), capacity_factor=8.0)
    ctx = make_ctx(cfg, None)
    params = api.model_init(jax.random.key(0), cfg)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)

    # teacher-forced full forward -> logits at every position
    hidden, _, _, _ = api.forward_hidden(params, {"tokens": tokens}, cfg, ctx,
                                         mode="train", remat=False)
    full_logits = T.lm_logits(params, hidden, cfg, ctx)

    # token-by-token decode from a zero cache
    cache = T.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    logits_seq = []
    for i in range(S):
        lg, cache = api.decode_step(params, cache, tokens[:, i:i + 1],
                                    jnp.asarray(i, jnp.int32), cfg, ctx)
        logits_seq.append(lg)
    dec_logits = jnp.stack(logits_seq, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), atol=5e-2, rtol=5e-2)


def test_prefill_cache_matches_decode_cache_contents():
    """Prefill's returned KV equals what decode writes token-by-token."""
    cfg = reduced(get_model_config("qwen3-1.7b"))
    ctx = make_ctx(cfg, None)
    params = api.model_init(jax.random.key(0), cfg)
    B, S = 1, 6
    tokens = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    _, pre_cache, _ = api.prefill(params, {"tokens": tokens}, cfg, ctx)

    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    for i in range(S):
        _, cache = api.decode_step(params, cache, tokens[:, i:i + 1],
                                   jnp.asarray(i, jnp.int32), cfg, ctx)
    # compare K buffers of the first scanned superblock position
    k_pre = np.asarray(pre_cache["blocks"]["l0"][0], np.float32)
    k_dec = np.asarray(cache["blocks"]["l0"][0], np.float32)
    np.testing.assert_allclose(k_pre, k_dec[:, :, :S][:, :, :k_pre.shape[2]]
                               if k_dec.ndim == k_pre.ndim else k_dec,
                               atol=2e-2, rtol=2e-2)
