"""Property tests (hypothesis) for Horn's parallel dropout invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import HornConfig
from repro.core import parallel_dropout as pdrop

SETTINGS = dict(max_examples=25, deadline=None)


@given(groups=st.integers(1, 8), units=st.integers(8, 300),
       keep=st.floats(0.2, 0.9), block=st.sampled_from([1, 4, 16, 128]),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_mask_values_and_connectivity(groups, units, keep, block, seed):
    """Masks take values in {0, 1/keep} and never kill an entire layer."""
    m = pdrop.group_block_mask(jax.random.key(seed), groups, units, keep, block)
    vals = np.unique(np.asarray(m))
    ok = np.isclose(vals, 0.0) | np.isclose(vals, 1.0 / keep, rtol=1e-5)
    assert ok.all(), vals
    assert (np.asarray(m).max(axis=-1) > 0).all(), "a group lost all blocks"


@given(keep=st.floats(0.3, 0.9), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_inverted_dropout_unbiased(keep, seed):
    """E[mask] ~= 1: train-time inverted scaling == paper's eval-time scaling
    in expectation (the equivalence noted in DESIGN.md §4)."""
    m = pdrop.group_block_mask(jax.random.key(seed), 512, 1024, keep, 1)
    assert abs(float(np.asarray(m).mean()) - 1.0) < 0.05


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_groups_draw_different_submodels(seed):
    """Different groups get different sub-models (the whole point)."""
    m = np.asarray(pdrop.group_block_mask(jax.random.key(seed), 8, 512, 0.5, 1))
    distinct = {tuple(row) for row in (m > 0).astype(int)}
    assert len(distinct) >= 7     # collisions astronomically unlikely


def test_mask_deterministic_per_step_and_layer():
    cfg = HornConfig(enabled=True, num_groups=4)
    s1 = pdrop.make_horn_state(jax.random.key(0), cfg, 4, step=3)
    s2 = pdrop.make_horn_state(jax.random.key(0), cfg, 4, step=3)
    m1 = pdrop.unit_mask(s1, 2, 8, 256)
    m2 = pdrop.unit_mask(s2, 2, 8, 256)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    s3 = pdrop.make_horn_state(jax.random.key(0), cfg, 4, step=4)
    m3 = pdrop.unit_mask(s3, 2, 8, 256)
    assert not np.array_equal(np.asarray(m1), np.asarray(m3))


def test_expand_mask_group_to_sample():
    mb = jnp.asarray([[2.0, 0.0], [0.0, 2.0]])
    m = np.asarray(pdrop.expand_mask(mb, 8, 4))    # [4, 1, 8]
    assert m.shape == (4, 1, 8)
    np.testing.assert_array_equal(m[0], m[1])      # samples of group 0 match
    assert not np.array_equal(m[0], m[2])


def test_eval_mode_returns_none():
    assert pdrop.unit_mask(None, 0, 4, 128) is None
    cfg = HornConfig(enabled=False)
    assert pdrop.make_horn_state(jax.random.key(0), cfg, 4, 0) is None


def test_batch_averaging_equals_large_batch_sgd():
    """Horn's claim basis: averaging G groups' grads on B/G samples each ==
    the gradient of the full batch (for a shared model, no dropout)."""
    from repro.core.neuron_centric import paper_mnist_network
    nn = paper_mnist_network(hidden=16, depth=1)
    nn.input_neuron = "standard"
    params = nn.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (16, 784))
    y = jax.random.randint(jax.random.key(2), (16,), 0, 10)
    full = jax.grad(nn.loss)(params, {"x": x, "y": y})
    gs = [jax.grad(nn.loss)(params, {"x": x[i::4], "y": y[i::4]})
          for i in range(4)]
    avg = jax.tree.map(lambda *g: sum(g) / 4, *gs)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)
