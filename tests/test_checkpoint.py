"""Checkpoint/restore, corruption fallback, elastic reshard, FT loop."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def make_state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = make_state(1.5)
    ck.save(7, state)
    restored, step = ck.restore(make_state(0.0))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, make_state(float(s)), blocking=False)
        ck.wait()
    assert ck.available_steps() == [3, 4]


def test_corruption_detected_and_fallback(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, make_state(1.0))
    ck.save(2, make_state(2.0))
    # corrupt step 2's payload
    path = os.path.join(str(tmp_path), "step_000000002", "shard_0.npz")
    data = dict(np.load(path))
    key = list(data)[0]
    data[key] = data[key] + 99.0
    np.savez(path, **data)
    with pytest.raises(ValueError):
        ck.restore(make_state(), step=2)
    restored, step = ck.restore_latest_good(make_state())
    assert step == 1
    assert float(np.asarray(restored["params"]["w"]).mean()) == 1.0


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, make_state(1.0))
    # simulate a preempted save: directory without _COMMITTED
    os.makedirs(os.path.join(str(tmp_path), "step_000000005"))
    assert ck.latest_step() == 1


def test_elastic_reshard_restore(tmp_path):
    """Restore onto a different mesh layout (device_put w/ new shardings)."""
    from repro.launch.mesh import make_test_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    state = make_state(3.0)
    ck.save(1, state)
    mesh = make_test_mesh()
    sh = {"params": {"w": NamedSharding(mesh, P(None, None)),
                     "b": NamedSharding(mesh, P(None))},
          "step": NamedSharding(mesh, P())}
    restored, _ = ck.restore(make_state(), shardings=sh)
    assert restored["params"]["w"].sharding.is_equivalent_to(
        sh["params"]["w"], 2)


def test_fault_tolerant_loop_nan_rollback(tmp_path):
    """A poisoned step triggers skip, then rollback to the last checkpoint."""
    from repro.runtime.fault_tolerance import (NanGuard, PreemptionHandler,
                                               fault_tolerant_loop)
    ck = Checkpointer(str(tmp_path), keep=5)
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        step = int(state["step"])
        poisoned = 5 <= calls["n"] <= 8 and step >= 4
        loss = float("nan") if poisoned else 1.0 / (step + 1)
        new = dict(state)
        new["step"] = state["step"] + 1
        new["params"] = jax.tree.map(lambda x: x + 1, state["params"])
        return new, {"loss": loss}

    state = {"params": {"w": jnp.zeros((2,))}, "step": jnp.asarray(0)}
    final, step, reason = fault_tolerant_loop(
        state=state, step_fn=step_fn, batch_at=lambda s: {},
        checkpointer=ck, num_steps=10, checkpoint_every=2,
        preemption=PreemptionHandler(signals=()),
        nan_guard=NanGuard(patience=2))
    assert reason == "completed"
    assert step == 10
    assert calls["n"] > 10          # retries happened


def test_preemption_checkpoint(tmp_path):
    from repro.runtime.fault_tolerance import (PreemptionHandler,
                                               fault_tolerant_loop)
    ck = Checkpointer(str(tmp_path))
    handler = PreemptionHandler(signals=())

    def step_fn(state, batch):
        if int(state["step"]) == 3:
            handler.trigger()       # simulate SIGTERM mid-run
        new = dict(state)
        new["step"] = state["step"] + 1
        return new, {"loss": 0.5}

    state = {"params": {"w": jnp.zeros((2,))}, "step": jnp.asarray(0)}
    final, step, reason = fault_tolerant_loop(
        state=state, step_fn=step_fn, batch_at=lambda s: {},
        checkpointer=ck, num_steps=100, checkpoint_every=50,
        preemption=handler)
    assert reason == "preempted"
    assert ck.latest_step() == step
