"""Sub-model planner tests: plan axes per family, materialized sub-model
equivalence (the paper's memory-reduction claim is mathematically exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HornConfig, get_model_config
from repro.core import submodel as SM


def test_plan_covers_families():
    horn = HornConfig()
    dense = SM.plan(get_model_config("qwen3-1.7b"), horn)
    assert any(a.name == "ffn_hidden" for a in dense)
    ssm = SM.plan(get_model_config("mamba2-2.7b"), horn)
    names = {a.name for a in ssm}
    assert "ssm_channels" in names and "ffn_hidden" not in names
    hybrid = SM.plan(get_model_config("jamba-1.5-large-398b"), horn)
    names = {a.name for a in hybrid}
    assert {"ssm_channels", "moe_hidden", "ffn_hidden"} <= names


def test_materialized_submodel_is_exact():
    """Running the kept-columns-only weights == running masked full weights:
    the sub-model is a genuinely smaller network, not an approximation."""
    rng = np.random.default_rng(0)
    d, ff, bs = 16, 64, 8
    wi = jnp.asarray(rng.normal(size=(d, ff)), jnp.float32)
    wo = jnp.asarray(rng.normal(size=(ff, d)), jnp.float32)
    mask_blocks = jnp.asarray([2.0, 0.0, 2.0, 0.0, 0.0, 2.0, 2.0, 0.0])
    x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)

    full_mask = jnp.repeat(mask_blocks, bs)
    y_masked = (jax.nn.relu(x @ wi) * full_mask) @ wo

    wi_k, wo_k = SM.materialize(wi, wo, mask_blocks, bs)
    assert wi_k.shape == (d, 32) and wo_k.shape == (32, d)   # half the units
    y_small = (jax.nn.relu(x @ wi_k) * 2.0) @ wo_k           # 1/keep scale
    np.testing.assert_allclose(np.asarray(y_small), np.asarray(y_masked),
                               atol=1e-5, rtol=1e-5)


def test_stats_tracks_keep_rate():
    horn = HornConfig(keep_hidden=0.5, keep_input=0.8, block_size=128)
    s = SM.stats(get_model_config("qwen3-1.7b"), horn, num_groups=32)
    assert abs(s["ffn_hidden_dropped_frac"] - 0.5) < 0.15
    assert abs(s["input_embed_dropped_frac"] - 0.2) < 0.15
