"""Property test: the symbolic verifier's verdicts match brute-force
concrete enumeration for randomly generated kernel geometries — no false
proofs (a symbolically-proved obligation the enumeration refutes) and no
false alarms (a symbolic refutation the enumeration proves).

Requires ``hypothesis`` (skipped where the toolchain image lacks it —
the deterministic agreement check in test_hornshape.py still runs the
same oracle over every committed kernel geometry).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.analysis.blockspec_verify import (Geometry, Operand, brute_force,
                                             verify)
from repro.analysis.symbolic import s_max, s_min, sym


@st.composite
def geometries(draw):
    rank = draw(st.integers(1, 3))
    grid = tuple(draw(st.integers(1, 4)) for _ in range(rank))
    ndim = draw(st.integers(1, 2))
    bs = tuple(draw(st.integers(1, 3)) for _ in range(ndim))
    nblocks = tuple(draw(st.integers(1, 4)) for _ in range(ndim))
    shape = tuple(b * n for b, n in zip(bs, nblocks))
    # affine index expression per output dim, optionally clamped into
    # range (clamped dims are in-bounds by construction; unclamped ones
    # exercise the OOB and coverage checks)
    coeffs = [tuple(draw(st.integers(-2, 3)) for _ in range(rank))
              for _ in range(ndim)]
    consts = [draw(st.integers(-2, 3)) for _ in range(ndim)]
    clamped = [draw(st.booleans()) for _ in range(ndim)]
    use_floordiv = [draw(st.booleans()) for _ in range(ndim)]

    def index_map(*gs):
        out = []
        for d in range(ndim):
            e = sym(consts[d])
            for c, g in zip(coeffs[d], gs):
                e = e + c * g
            if use_floordiv[d]:
                e = e // 2
            if clamped[d]:
                e = s_max(s_min(e, nblocks[d] - 1), 0)
            out.append(e)
        return tuple(out)

    in_map = lambda *gs: tuple(gs[:1])      # noqa: E731 — trivially safe
    geom = Geometry(
        name="prop", grid=grid,
        in_operands=[Operand("in0", (grid[0] * 2,), "float32", (2,),
                             in_map, None)],
        out_operands=[Operand("out0", shape, "float32", bs,
                              index_map, None)])
    return geom


@settings(max_examples=80, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(geometries())
def test_symbolic_verdicts_agree_with_enumeration(geom):
    rep = verify(geom)
    truth = brute_force(geom)
    for key, expected in truth.items():
        got = rep.verdicts.get(key)
        if got is None:
            continue                  # obligation not discharged (HS006)
        assert got == expected, (
            f"{key}: symbolic verdict {got!r} != enumerated {expected!r} "
            f"for grid={geom.grid} map "
            f"(proved symbolically: {rep.methods.get(key)})")


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(geometries())
def test_no_false_proofs(geom):
    # stronger framing of the same oracle: anything the prover discharged
    # *symbolically* must hold under exhaustive enumeration
    rep = verify(geom)
    truth = brute_force(geom)
    for key, method in rep.methods.items():
        if method != "symbolic" or key not in truth:
            continue
        if isinstance(truth[key], bool):
            assert rep.verdicts[key] == truth[key], \
                f"false {'proof' if rep.verdicts[key] else 'alarm'} at {key}"
